//! Dense tensors: `Mat` (2-D f32, row-major — the linalg workhorse) and
//! `Tensor` (n-D f32) + `IntTensor` (i32 token buffers) shared across the
//! native runtime, the compression engine, and the checkpoint format.

use crate::util::rng::Rng;

// ---------------------------------------------------------------------------
// Mat
// ---------------------------------------------------------------------------

/// Row-major dense f32 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Mat {
        assert_eq!(data.len(), rows * cols, "Mat::from_vec shape mismatch");
        Mat { rows, cols, data }
    }

    pub fn eye(n: usize) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    pub fn randn(rng: &mut Rng, rows: usize, cols: usize, std: f32) -> Mat {
        let mut m = Mat::zeros(rows, cols);
        rng.fill_normal(&mut m.data, 0.0, std);
        m
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Copy `src` over row `r` (KV-cache appends, factor re-shaping).
    #[inline]
    pub fn set_row(&mut self, r: usize, src: &[f32]) {
        self.row_mut(r).copy_from_slice(src);
    }

    pub fn transpose(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        // blocked transpose for cache friendliness on larger matrices
        const B: usize = 32;
        for rb in (0..self.rows).step_by(B) {
            for cb in (0..self.cols).step_by(B) {
                for r in rb..(rb + B).min(self.rows) {
                    for c in cb..(cb + B).min(self.cols) {
                        out.data[c * self.rows + r] = self.data[r * self.cols + c];
                    }
                }
            }
        }
        out
    }

    pub fn scale(&mut self, s: f32) {
        for v in &mut self.data {
            *v *= s;
        }
    }

    pub fn add_assign(&mut self, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    pub fn sub(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }

    pub fn add(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a + b).collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }

    pub fn scaled(&self, s: f32) -> Mat {
        let mut out = self.clone();
        out.scale(s);
        out
    }

    /// Frobenius inner product <A, B> = tr(A^T B).
    pub fn dot(&self, other: &Mat) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| *a as f64 * *b as f64)
            .sum()
    }

    pub fn frob_norm(&self) -> f64 {
        self.dot(self).sqrt()
    }

    /// Add `lambda` to the diagonal (ridge for whitening stability).
    pub fn add_diag(&mut self, lambda: f32) {
        let n = self.rows.min(self.cols);
        for i in 0..n {
            self.data[i * self.cols + i] += lambda;
        }
    }

    pub fn diag(&self) -> Vec<f32> {
        (0..self.rows.min(self.cols)).map(|i| self.at(i, i)).collect()
    }

    pub fn is_finite(&self) -> bool {
        self.data.iter().all(|v| v.is_finite())
    }
}

// ---------------------------------------------------------------------------
// Tensor (n-D f32) and IntTensor (n-D i32)
// ---------------------------------------------------------------------------

#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn scalar(v: f32) -> Tensor {
        Tensor { shape: vec![], data: vec![v] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(data.len(), shape.iter().product::<usize>());
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// View a 2-D tensor as a Mat (copy).
    pub fn to_mat(&self) -> Mat {
        assert_eq!(self.shape.len(), 2, "to_mat wants 2-D, got {:?}", self.shape);
        Mat::from_vec(self.shape[0], self.shape[1], self.data.clone())
    }

    pub fn from_mat(m: &Mat) -> Tensor {
        Tensor { shape: vec![m.rows, m.cols], data: m.data.clone() }
    }
}

#[derive(Clone, Debug, PartialEq)]
pub struct IntTensor {
    pub shape: Vec<usize>,
    pub data: Vec<i32>,
}

impl IntTensor {
    pub fn from_vec(shape: &[usize], data: Vec<i32>) -> IntTensor {
        assert_eq!(data.len(), shape.iter().product::<usize>());
        IntTensor { shape: shape.to_vec(), data }
    }

    pub fn scalar(v: i32) -> IntTensor {
        IntTensor { shape: vec![], data: vec![v] }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mat_indexing_roundtrip() {
        let mut m = Mat::zeros(3, 4);
        *m.at_mut(1, 2) = 5.0;
        assert_eq!(m.at(1, 2), 5.0);
        assert_eq!(m.row(1)[2], 5.0);
    }

    #[test]
    fn set_row_copies_whole_row() {
        let mut m = Mat::zeros(2, 3);
        m.set_row(1, &[1.0, 2.0, 3.0]);
        assert_eq!(m.row(0), &[0.0; 3]);
        assert_eq!(m.row(1), &[1.0, 2.0, 3.0]);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(1);
        let m = Mat::randn(&mut rng, 37, 53, 1.0);
        assert_eq!(m.transpose().transpose(), m);
    }

    #[test]
    fn transpose_entries() {
        let m = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let t = m.transpose();
        assert_eq!(t.at(2, 1), m.at(1, 2));
        assert_eq!((t.rows, t.cols), (3, 2));
    }

    #[test]
    fn frob_and_dot() {
        let a = Mat::from_vec(2, 2, vec![1., 2., 3., 4.]);
        assert!((a.frob_norm() - (30.0f64).sqrt()).abs() < 1e-9);
        let b = Mat::eye(2);
        assert!((a.dot(&b) - 5.0).abs() < 1e-9); // trace
    }

    #[test]
    fn add_diag_ridge() {
        let mut m = Mat::zeros(3, 3);
        m.add_diag(0.5);
        assert_eq!(m.diag(), vec![0.5, 0.5, 0.5]);
    }

    #[test]
    fn tensor_mat_roundtrip() {
        let mut rng = Rng::new(2);
        let m = Mat::randn(&mut rng, 4, 5, 1.0);
        let t = Tensor::from_mat(&m);
        assert_eq!(t.to_mat(), m);
    }

    #[test]
    fn tensor_shapes() {
        let t = Tensor::zeros(&[2, 3, 4]);
        assert_eq!(t.len(), 24);
        let s = Tensor::scalar(7.0);
        assert_eq!(s.shape, Vec::<usize>::new());
        assert_eq!(s.data, vec![7.0]);
    }
}
