//! Kernel-backend equivalence gate: the AVX2 and portable SIMD backends
//! (`linalg::kernels`) must be **bit-identical** on every shape — that is
//! the whole contract of the micro-kernel layer, and what makes results
//! reproducible across ISAs.
//!
//! Sections:
//!
//! 1. primitive kernels (`dot_f32`, `axpy_f32`, the f64 row reductions) on
//!    adversarial payloads — every remainder lane (lengths 0..=65),
//!    unaligned slice starts, zero rows, signed zeros, denormals;
//! 2. the GEMM kernels through `matmul` / `matmul_bt` / `gram` across tile
//!    remainders, plus proptest-style random shapes (`util::prop::forall`);
//! 3. end-to-end: full-forward and KV-cached decode logits (dense and
//!    low-rank), at threads {1, 4}, bit-identical across backends.
//!
//! Everything lives in ONE test function: `force_backend` (and
//! `exec::set_threads`) are process-global, and this harness would
//! otherwise race against itself.  On hosts without AVX2 the forced-AVX2
//! runs resolve to the portable backend and the comparisons hold
//! trivially; the ci.sh `PALLAS_NO_SIMD=1` lane separately re-runs the
//! whole suite on the portable backend.

use std::collections::BTreeMap;

use zs_svd::exec;
use zs_svd::linalg::kernels::{self, Backend};
use zs_svd::linalg::{axpy_f32, dot_f32, gram, matmul, matmul_bt};
use zs_svd::model::init::init_params;
use zs_svd::runtime::session::Session;
use zs_svd::runtime::Runtime;
use zs_svd::tensor::{IntTensor, Mat};
use zs_svd::util::prop::forall;
use zs_svd::util::rng::Rng;

/// Run `f` under a forced backend, restoring automatic resolution after.
fn with_backend<T>(b: Backend, f: impl FnOnce() -> T) -> T {
    kernels::force_backend(Some(b));
    let out = f();
    kernels::force_backend(None);
    out
}

/// Adversarial f32 payload: normals across magnitudes, exact and signed
/// zeros, denormals — everything the bit-identity contract must survive.
fn adversarial(rng: &mut Rng, len: usize) -> Vec<f32> {
    (0..len)
        .map(|i| match i % 7 {
            0 => 0.0,
            1 => -0.0,
            2 => f32::from_bits(1 + (i as u32 % 9)), // denormals
            3 => -f32::from_bits(3 + (i as u32 % 5)),
            4 => (rng.uniform() as f32 - 0.5) * 1e-20,
            5 => (rng.uniform() as f32 - 0.5) * 1e20,
            _ => rng.uniform() as f32 - 0.5,
        })
        .collect()
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|f| f.to_bits()).collect()
}

fn assert_mat_bits_eq(a: &Mat, b: &Mat, what: &str) {
    assert_eq!((a.rows, a.cols), (b.rows, b.cols), "{what}: shape");
    assert_eq!(bits(&a.data), bits(&b.data), "{what}: bits differ");
}

/// Uniform-rank random factors matching the artifact ranks of `tag`
/// (the `decode_parity.rs` helper).
fn synthetic_factors(sess: &Session, tag: &str, rng: &mut Rng)
                     -> BTreeMap<String, (Mat, Mat)> {
    let lm = sess.cfg.lowrank.get(tag).expect("artifact tag");
    sess.cfg
        .targets
        .iter()
        .map(|t| {
            let (m, n) = t.shape;
            let k = lm.ranks[&t.name];
            (t.name.clone(),
             (Mat::randn(rng, m, k, 0.05), Mat::randn(rng, k, n, 0.05)))
        })
        .collect()
}

#[test]
fn simd_and_portable_backends_are_bit_identical() {
    if !kernels::simd_available() {
        eprintln!("note: no AVX2 on this host — forced-AVX2 runs resolve to \
                   portable and this gate only checks self-consistency");
    }

    // ---- primitives: every remainder lane × unaligned starts ----
    let mut rng = Rng::new(0x51D);
    for len in 0..=65usize {
        for off in [0usize, 1, 3, 5] {
            let a = adversarial(&mut rng, len + off);
            let b = adversarial(&mut rng, len + off);
            let (sa, sb) = (&a[off..], &b[off..]);

            let dp = with_backend(Backend::Portable, || dot_f32(sa, sb));
            let dv = with_backend(Backend::Avx2, || dot_f32(sa, sb));
            assert_eq!(dp.to_bits(), dv.to_bits(),
                       "dot len {len} off {off}: {dp} vs {dv}");

            let sp = with_backend(Backend::Portable,
                                  || (kernels::sum_f64(sa),
                                      kernels::sum_sq_f64(sa),
                                      kernels::sum_sq_centered_f64(sa, 0.31)));
            let sv = with_backend(Backend::Avx2,
                                  || (kernels::sum_f64(sa),
                                      kernels::sum_sq_f64(sa),
                                      kernels::sum_sq_centered_f64(sa, 0.31)));
            assert_eq!(sp.0.to_bits(), sv.0.to_bits(), "sum len {len}");
            assert_eq!(sp.1.to_bits(), sv.1.to_bits(), "sum_sq len {len}");
            assert_eq!(sp.2.to_bits(), sv.2.to_bits(), "centered len {len}");

            let y0 = adversarial(&mut rng, len);
            let mut yp = y0.clone();
            let mut yv = y0;
            with_backend(Backend::Portable, || axpy_f32(&mut yp, 0.37, sa));
            with_backend(Backend::Avx2, || axpy_f32(&mut yv, 0.37, sa));
            assert_eq!(bits(&yp), bits(&yv), "axpy len {len} off {off}");
        }
    }

    // ---- GEMM kernels across tile remainders (rows % 4, cols % 16,
    // k % 8), zero rows included via the adversarial payload ----
    for &(m, k, n) in &[(1usize, 7usize, 15usize), (1, 128, 512), (2, 0, 4),
                        (4, 8, 16), (5, 9, 17), (8, 64, 48), (3, 65, 33),
                        (16, 129, 31), (33, 64, 65)] {
        let a = Mat::from_vec(m, k, adversarial(&mut rng, m * k));
        let b = Mat::from_vec(k, n, adversarial(&mut rng, k * n));
        let bt = Mat::from_vec(n, k, adversarial(&mut rng, n * k));
        let p = with_backend(Backend::Portable,
                             || (matmul(&a, &b), matmul_bt(&a, &bt), gram(&a)));
        let v = with_backend(Backend::Avx2,
                             || (matmul(&a, &b), matmul_bt(&a, &bt), gram(&a)));
        assert_mat_bits_eq(&p.0, &v.0, &format!("matmul {m}x{k}x{n}"));
        assert_mat_bits_eq(&p.1, &v.1, &format!("matmul_bt {m}x{k}x{n}"));
        assert_mat_bits_eq(&p.2, &v.2, &format!("gram {m}x{k}"));
    }

    // ---- proptest-style random shapes ----
    forall("kernel-backend-bitmatch", 32, |rng| {
        let m = rng.range(1, 40);
        let k = rng.range(1, 70);
        let n = rng.range(1, 70);
        let a = Mat::randn(rng, m, k, 1.0);
        let b = Mat::randn(rng, k, n, 1.0);
        let bt = Mat::randn(rng, n, k, 1.0);
        (a, b, bt)
    }, |(a, b, bt)| {
        let p = with_backend(Backend::Portable,
                             || (matmul(a, b), matmul_bt(a, bt), gram(a)));
        let v = with_backend(Backend::Avx2,
                             || (matmul(a, b), matmul_bt(a, bt), gram(a)));
        if bits(&p.0.data) != bits(&v.0.data) {
            return Err(format!("matmul {}x{}x{}", a.rows, a.cols, b.cols));
        }
        if bits(&p.1.data) != bits(&v.1.data) {
            return Err(format!("matmul_bt {}x{}x{}", a.rows, a.cols, bt.rows));
        }
        if bits(&p.2.data) != bits(&v.2.data) {
            return Err(format!("gram {}x{}", a.rows, a.cols));
        }
        Ok(())
    });

    // ---- end-to-end: forward + KV-cached decode, dense and low-rank,
    // threads {1, 4} — the whole runtime stack must be backend-invariant ----
    let rt = Runtime::load_default().unwrap();
    let sess = Session::new(&rt, "tiny");
    let mut prng = Rng::new(0xE2E);
    let params = init_params(&sess.cfg, &mut prng);
    let tag = "60";
    let factors = synthetic_factors(&sess, tag, &mut prng);
    let seq = sess.cfg.seq_len;
    let tokens: Vec<i32> = (0..seq + 1)
        .map(|_| prng.range(1, sess.cfg.vocab) as i32)
        .collect();
    let full = IntTensor::from_vec(&[1, seq + 1], tokens.clone());

    for threads in [1usize, 4] {
        exec::set_threads(threads);
        let run = || {
            let (loss, logits) = sess.fwd(&params, &full).unwrap();
            let (_, lr_logits) =
                sess.lowrank_fwd(tag, &params, &factors, &full).unwrap();
            let mut cache = sess.new_kv_cache();
            let steps: Vec<Vec<f32>> = tokens[..seq]
                .iter()
                .map(|&t| {
                    sess.decode_step(&params, &mut cache, t).unwrap().data
                })
                .collect();
            (loss, logits.data, lr_logits.data, steps)
        };
        let p = with_backend(Backend::Portable, &run);
        let v = with_backend(Backend::Avx2, &run);
        assert_eq!(p.0.to_bits(), v.0.to_bits(),
                   "loss differs across backends @ {threads} threads");
        assert_eq!(bits(&p.1), bits(&v.1),
                   "forward logits differ across backends @ {threads} threads");
        assert_eq!(bits(&p.2), bits(&v.2),
                   "lowrank logits differ across backends @ {threads} threads");
        for (pos, (sp, sv)) in p.3.iter().zip(&v.3).enumerate() {
            assert_eq!(bits(sp), bits(sv),
                       "decode step {pos} differs across backends \
                        @ {threads} threads");
        }
    }
    exec::set_threads(0);
}
