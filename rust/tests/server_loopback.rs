//! Network serving gates.
//!
//! 1. **Loopback bit-match** — N concurrent TCP clients stream generations
//!    that reproduce the offline `decode::run_decode` tokens BIT-EXACTLY
//!    for the same prompts / temperatures / seeds, on both the dense and a
//!    low-rank engine, at thread counts {1, 4} and prefill chunk sizes
//!    {1, 3, whole-prompt} (the offline reference always runs whole-prompt,
//!    so the sweep also proves chunk-size invariance over the wire).
//!    Everything thread-global lives in one test function
//!    (`exec::set_threads` is process-wide, the `parallel_equiv.rs`
//!    pattern).  ci.sh re-runs this gate under `PALLAS_NO_SIMD=1`, so the
//!    wire bit-match holds on both kernel backends (backend bit-identity
//!    itself is `rust/tests/kernel_equiv.rs`'s job; `force_backend` is
//!    process-global and never flipped here).
//! 2. **Backpressure** — with one slot busy and the admission queue full,
//!    further requests get a structured `overloaded` reply (never a silent
//!    drop), every admitted request completes exactly once, and the server
//!    keeps serving afterwards.
//! 3. **Hot-swap bit-identity** — a server started on a packed artifact A
//!    accepts a `reload` to artifact B while a generation is in flight:
//!    the in-flight request completes entirely on A (bit-matching A's
//!    offline reference), every post-swap request bit-matches B's offline
//!    reference, a corrupted artifact is rejected with a structured
//!    `reload_failed` error naming the bad chunk while A keeps serving,
//!    and the `artifact.swaps` counter crosses the wire — swept over
//!    thread counts {1, 4} × speculation depths {0, 2}.

use std::collections::BTreeMap;
use std::net::SocketAddr;
use std::path::Path;
use std::sync::mpsc;

use zs_svd::artifact::store::read_manifest_file;
use zs_svd::artifact::{self, ChunkClass, ChunkStore};
use zs_svd::decode::{run_decode, DecodeConfig, DecodeRequest, EngineSlot};
use zs_svd::exec;
use zs_svd::model::init::init_params;
use zs_svd::model::ParamStore;
use zs_svd::runtime::session::Session;
use zs_svd::runtime::Runtime;
use zs_svd::serve::Engine;
use zs_svd::server::protocol::{Event, ERR_BAD_REQUEST, ERR_OVERLOADED,
                               ERR_RELOAD_FAILED};
use zs_svd::server::{self, Client, GenerateOutcome, GenerateReq, ReloadOutcome,
                     Request, ServerConfig};
use zs_svd::tensor::Mat;
use zs_svd::util::rng::Rng;

const CLIENTS: usize = 4;
const PER_CLIENT: usize = 2;
const PROMPT_LEN: usize = 8;
const MAX_NEW: usize = 6;

/// Uniform-rank random factors matching the artifact ranks of `tag` — valid
/// for both the prefill and decode low-rank entry points.
fn synthetic_factors(sess: &Session, tag: &str, rng: &mut Rng)
                     -> BTreeMap<String, (Mat, Mat)> {
    let lm = sess.cfg.lowrank.get(tag).expect("artifact tag");
    sess.cfg
        .targets
        .iter()
        .map(|t| {
            let (m, n) = t.shape;
            let k = lm.ranks[&t.name];
            (t.name.clone(),
             (Mat::randn(rng, m, k, 0.05), Mat::randn(rng, k, n, 0.05)))
        })
        .collect()
}

/// Deterministic prompt for logical request `k` (same on the wire and in
/// the offline reference).
fn prompt_for(k: usize, vocab: usize) -> Vec<i32> {
    let mut rng = Rng::new(0x5EED ^ (k as u64));
    (0..PROMPT_LEN).map(|_| rng.range(1, vocab) as i32).collect()
}

/// Sampling settings for logical request `k`: alternate greedy and
/// explicit-seed temperature sampling so both paths cross the wire.
fn sampling_for(k: usize) -> (Option<f32>, Option<u64>) {
    if k % 2 == 0 {
        (Some(0.0), None)
    } else {
        (Some(0.7), Some(5000 + k as u64))
    }
}

/// One loopback round: serve `engine` over TCP at the given prefill chunk
/// size (optionally speculating through `drafter` at depth `speculate_k`),
/// drive it with concurrent clients, and return the tokens each logical
/// request streamed.
fn serve_and_collect(sess: &Session, params: &ParamStore, engine: &Engine,
                     drafter: Option<&Engine>, speculate_k: usize,
                     prefill_chunk: usize) -> Vec<(usize, Vec<i32>)> {
    let vocab = sess.cfg.vocab;
    let cfg = ServerConfig {
        addr: "127.0.0.1:0".into(),
        queue_depth: 64,
        decode: DecodeConfig { max_slots: 3, max_new_tokens: MAX_NEW,
                               temperature: 0.0, seed: 9, arrival_steps: 0.0,
                               prefill_chunk, speculate_k,
                               ..DecodeConfig::default() },
    };
    let (tx, rx) = mpsc::channel::<SocketAddr>();
    let mut collected: Vec<(usize, Vec<i32>)> = Vec::new();

    std::thread::scope(|s| {
        let cfg = &cfg;
        let srv = s.spawn(move || {
            server::run(sess, params, engine, drafter, cfg, move |a| {
                tx.send(a).expect("report addr");
            })
        });
        let addr = rx.recv().expect("server bound");

        let handles: Vec<_> = (0..CLIENTS)
            .map(|c| {
                s.spawn(move || {
                    let mut cl = Client::connect(addr).expect("connect");
                    let mut out = Vec::new();
                    for i in 0..PER_CLIENT {
                        let k = c * PER_CLIENT + i;
                        let (temperature, seed) = sampling_for(k);
                        let g = GenerateReq {
                            id: k as u64,
                            prompt: prompt_for(k, vocab),
                            max_new_tokens: MAX_NEW,
                            temperature,
                            seed,
                        };
                        match cl.run_generate(&g).expect("generate") {
                            GenerateOutcome::Done(r) => {
                                // stream discipline is asserted inside
                                // run_generate; record the final tokens
                                assert_eq!(r.tokens.len(), MAX_NEW,
                                           "request {k} budget");
                                assert!(r.latency_ms >= r.ttft_ms);
                                out.push((k, r.tokens));
                            }
                            GenerateOutcome::Rejected { code, message, .. } => {
                                panic!("request {k} rejected: {code} \
                                        ({message})");
                            }
                        }
                    }
                    out
                })
            })
            .collect();
        for h in handles {
            collected.extend(h.join().expect("client thread"));
        }

        let mut cl = Client::connect(addr).expect("connect for shutdown");
        cl.shutdown_server().expect("shutdown");
        let stats = srv.join().expect("server thread").expect("server run");
        assert_eq!(stats.counters.requests_completed, CLIENTS * PER_CLIENT);
        assert_eq!(stats.requests_admitted as usize, CLIENTS * PER_CLIENT);
        assert_eq!(stats.requests_rejected, 0);
        assert_eq!(stats.counters.decode_tokens, CLIENTS * PER_CLIENT * MAX_NEW);
        assert!(stats.e2e.p99 >= stats.e2e.p50);
    });

    collected.sort_by_key(|(k, _)| *k);
    collected
}

/// Offline reference for the same logical requests.
fn offline_reference(sess: &Session, params: &ParamStore, engine: &Engine)
                     -> Vec<Vec<i32>> {
    let reqs: Vec<DecodeRequest> = (0..CLIENTS * PER_CLIENT)
        .map(|k| {
            let (temperature, seed) = sampling_for(k);
            DecodeRequest {
                id: k,
                prompt: prompt_for(k, sess.cfg.vocab),
                max_new_tokens: MAX_NEW,
                temperature,
                seed,
            }
        })
        .collect();
    // whole-prompt prefill: the fixed reference every chunked server run
    // must reproduce
    let dc = DecodeConfig { max_slots: 3, max_new_tokens: MAX_NEW,
                            temperature: 0.0, seed: 9, arrival_steps: 0.0,
                            prefill_chunk: 0, speculate_k: 0,
                            ..DecodeConfig::default() };
    let (_, done) = run_decode(sess, params, engine, &reqs, &dc)
        .expect("offline decode");
    done.into_iter().map(|c| c.tokens).collect()
}

#[test]
fn streamed_tokens_bitmatch_offline_for_both_engines() {
    let rt = Runtime::load_default().unwrap();
    let sess = Session::new(&rt, "tiny");
    let mut rng = Rng::new(0x10BAC);
    let params = init_params(&sess.cfg, &mut rng);
    let factors = synthetic_factors(&sess, "60", &mut rng);
    let lowrank = Engine::Lowrank { tag: "60".into(), factors };

    // chunk sizes {1, 3, whole-prompt}: the offline reference is computed
    // once per engine at whole-prompt prefill, so every chunked server run
    // matching it proves both network parity AND chunk-size invariance
    for threads in [1usize, 4] {
        exec::set_threads(threads);
        for engine in [&Engine::Dense, &lowrank] {
            let offline = offline_reference(&sess, &params, engine);
            for prefill_chunk in [1usize, 3, 0] {
                let served = serve_and_collect(&sess, &params, engine, None,
                                               0, prefill_chunk);
                assert_eq!(served.len(), CLIENTS * PER_CLIENT);
                for (k, tokens) in &served {
                    assert_eq!(tokens, &offline[*k],
                               "engine {} request {k} @ {threads} threads, \
                                prefill chunk {prefill_chunk}: network \
                                generation must bit-match offline",
                               engine.label());
                }
            }
        }
    }
    exec::set_threads(0);
}

#[test]
fn speculative_server_bitmatches_offline_and_reports_acceptance() {
    // a dense server speculating through a low-rank drafter must stream
    // tokens bit-identical to the plain offline dense reference (mixed
    // greedy/temperature clients — temperature slots fall back to plain
    // decode), and the drafter counters must surface in the wire metrics
    let rt = Runtime::load_default().unwrap();
    let sess = Session::new(&rt, "tiny");
    let mut rng = Rng::new(0x5BEC2);
    let params = init_params(&sess.cfg, &mut rng);
    let drafter = Engine::Lowrank {
        tag: "60".into(),
        factors: synthetic_factors(&sess, "60", &mut rng),
    };

    let offline = offline_reference(&sess, &params, &Engine::Dense);
    let served = serve_and_collect(&sess, &params, &Engine::Dense,
                                   Some(&drafter), 2, 3);
    assert_eq!(served.len(), CLIENTS * PER_CLIENT);
    for (k, tokens) in &served {
        assert_eq!(tokens, &offline[*k],
                   "request {k}: speculative server must bit-match the \
                    plain offline dense path");
    }

    // one more round just for the metrics surface
    let cfg = ServerConfig {
        addr: "127.0.0.1:0".into(),
        queue_depth: 8,
        decode: DecodeConfig { max_slots: 2, max_new_tokens: MAX_NEW,
                               temperature: 0.0, seed: 9, arrival_steps: 0.0,
                               prefill_chunk: 0, speculate_k: 2,
                               ..DecodeConfig::default() },
    };
    let (tx, rx) = mpsc::channel::<SocketAddr>();
    std::thread::scope(|s| {
        let cfg = &cfg;
        let sess = &sess;
        let params = &params;
        let drafter = &drafter;
        let srv = s.spawn(move || {
            server::run(sess, params, &Engine::Dense, Some(drafter), cfg,
                        move |a| { tx.send(a).expect("report addr"); })
        });
        let addr = rx.recv().expect("server bound");
        let mut cl = Client::connect(addr).expect("connect");
        let g = GenerateReq { id: 0, prompt: prompt_for(0, sess.cfg.vocab),
                              max_new_tokens: MAX_NEW,
                              temperature: Some(0.0), seed: None };
        match cl.run_generate(&g).expect("generate") {
            GenerateOutcome::Done(r) => {
                assert_eq!(r.tokens, offline[0]);
                assert!(!r.truncated, "nothing was cut short");
            }
            GenerateOutcome::Rejected { code, message, .. } => {
                panic!("rejected: {code} ({message})");
            }
        }
        let snap = cl.metrics().expect("metrics");
        let counters = snap.get("counters").expect("counters object");
        assert!(counters.usize_or("draft_proposed_tokens", 0) >= 1,
                "a greedy generation under speculation must draft");
        let rate = snap.f64_or("draft_acceptance_rate", -1.0);
        assert!((0.0..=1.0).contains(&rate), "rate {rate}");
        cl.shutdown_server().expect("shutdown");
        let stats = srv.join().expect("server thread").expect("server run");
        assert!(stats.counters.drafted_tokens >= 1);
        assert_eq!(stats.engine, "dense+spec-k2");
    });
}

#[test]
fn capacity_truncation_and_zero_budget_over_the_wire() {
    // the two admission/retirement edges the wire must surface: a prompt
    // that fills the KV arena completes with exactly one token and
    // `truncated: true`, and a request whose budget RESOLVES to zero (no
    // client budget, no server default) gets a structured bad_request
    let rt = Runtime::load_default().unwrap();
    let sess = Session::new(&rt, "tiny");
    let mut rng = Rng::new(0xEDF);
    let params = init_params(&sess.cfg, &mut rng);
    let seq = sess.cfg.seq_len;

    let cfg = ServerConfig {
        addr: "127.0.0.1:0".into(),
        queue_depth: 8,
        // a server deliberately configured with NO default budget
        decode: DecodeConfig { max_slots: 1, max_new_tokens: 0,
                               temperature: 0.0, seed: 3, arrival_steps: 0.0,
                               prefill_chunk: 0, speculate_k: 0,
                               ..DecodeConfig::default() },
    };
    let (tx, rx) = mpsc::channel::<SocketAddr>();
    std::thread::scope(|s| {
        let cfg = &cfg;
        let sess = &sess;
        let params = &params;
        let srv = s.spawn(move || {
            server::run(sess, params, &Engine::Dense, None, cfg, move |a| {
                tx.send(a).expect("report addr");
            })
        });
        let addr = rx.recv().expect("server bound");
        let mut cl = Client::connect(addr).expect("connect");

        // arena-filling prompt: one token, flagged truncated
        let g = GenerateReq { id: 0, prompt: vec![1i32; seq],
                              max_new_tokens: 10, temperature: Some(0.0),
                              seed: None };
        match cl.run_generate(&g).expect("generate") {
            GenerateOutcome::Done(r) => {
                assert_eq!(r.tokens.len(), 1,
                           "a full arena leaves room for exactly the \
                            prompt-logits token");
                assert!(r.truncated, "the capacity cut must cross the wire");
            }
            GenerateOutcome::Rejected { code, message, .. } => {
                panic!("rejected: {code} ({message})");
            }
        }

        // zero resolved budget: structured rejection, not a silent 1-token
        // generation (the old scheduler coerced 0 to 1)
        let g = GenerateReq { id: 1, prompt: prompt_for(1, sess.cfg.vocab),
                              max_new_tokens: 0, temperature: Some(0.0),
                              seed: None };
        match cl.run_generate(&g).expect("generate") {
            GenerateOutcome::Rejected { code, .. } => {
                assert_eq!(code, ERR_BAD_REQUEST);
            }
            GenerateOutcome::Done(r) => {
                panic!("zero budget must be rejected, got {} tokens",
                       r.tokens.len());
            }
        }

        cl.shutdown_server().expect("shutdown");
        let stats = srv.join().expect("server thread").expect("server run");
        assert_eq!(stats.counters.requests_completed, 1);
    });
}

#[test]
fn queue_full_gets_overloaded_and_server_stays_live() {
    let rt = Runtime::load_default().unwrap();
    let sess = Session::new(&rt, "tiny");
    let mut rng = Rng::new(0xBACC);
    let params = init_params(&sess.cfg, &mut rng);
    let vocab = sess.cfg.vocab;

    // one slot + depth-1 queue: at most 2 requests in the system; a fast
    // burst of 5 must see at least one structured rejection
    const BURST: usize = 5;
    let cfg = ServerConfig {
        addr: "127.0.0.1:0".into(),
        queue_depth: 1,
        decode: DecodeConfig { max_slots: 1, max_new_tokens: 24,
                               temperature: 0.0, seed: 3, arrival_steps: 0.0,
                               prefill_chunk: 0, speculate_k: 0,
                               ..DecodeConfig::default() },
    };
    let (tx, rx) = mpsc::channel::<SocketAddr>();

    std::thread::scope(|s| {
        let cfg = &cfg;
        let sess = &sess;
        let params = &params;
        let srv = s.spawn(move || {
            server::run(sess, params, &Engine::Dense, None, cfg, move |a| {
                tx.send(a).expect("report addr");
            })
        });
        let addr = rx.recv().expect("server bound");

        let mut cl = Client::connect(addr).expect("connect");
        // pipeline the whole burst without reading replies, so the queue
        // sees the requests back-to-back while slot 0 is busy generating
        for k in 0..BURST {
            cl.send(&Request::Generate(GenerateReq {
                id: k as u64,
                prompt: prompt_for(k, vocab),
                max_new_tokens: 24,
                temperature: Some(0.0),
                seed: None,
            }))
            .expect("send");
        }

        // collect exactly one terminal outcome per request id
        let mut outcomes: BTreeMap<u64, &'static str> = BTreeMap::new();
        let mut tokens_seen: BTreeMap<u64, usize> = BTreeMap::new();
        while outcomes.len() < BURST {
            match cl.next_event().expect("event").expect("open stream") {
                Event::Token { id, index, token } => {
                    let n = tokens_seen.entry(id).or_insert(0);
                    assert_eq!(index, *n, "sequential stream for {id}");
                    *n += 1;
                    assert!(token >= 0 && (token as usize) < vocab);
                    assert!(!outcomes.contains_key(&id),
                            "token after terminal event for {id}");
                }
                Event::Done { id, tokens, .. } => {
                    assert_eq!(tokens.len(), tokens_seen.get(&id).copied()
                               .unwrap_or(0), "done matches stream for {id}");
                    let prev = outcomes.insert(id, "done");
                    assert!(prev.is_none(), "request {id} completed twice");
                }
                Event::Error { id, code, queue_depth, retry_after_ms, .. } => {
                    let id = id.expect("rejections carry the request id");
                    assert_eq!(code, ERR_OVERLOADED,
                               "only overload rejections expected");
                    // overload rejections carry actionable back-off hints
                    let qd = queue_depth.expect("overloaded carries \
                                                 queue_depth");
                    assert!(qd <= cfg.queue_depth,
                            "queued-ahead {qd} cannot exceed the configured \
                             depth {}", cfg.queue_depth);
                    let hint = retry_after_ms.expect("overloaded carries \
                                                      retry_after_ms");
                    assert!(hint >= 1, "a zero hint would tell clients to \
                                        hammer the server");
                    let prev = outcomes.insert(id, "overloaded");
                    assert!(prev.is_none(), "request {id} rejected twice");
                }
                other => panic!("unexpected event: {other:?}"),
            }
        }
        let done = outcomes.values().filter(|v| **v == "done").count();
        let rejected = outcomes.values().filter(|v| **v == "overloaded").count();
        assert_eq!(done + rejected, BURST);
        assert!(rejected >= 1, "a depth-1 queue must reject part of a \
                                5-deep burst (done {done})");
        assert!(done >= 1, "the slot must have served part of the burst");
        // the first request is admitted before the queue can fill
        assert_eq!(outcomes.get(&0).copied(), Some("done"));

        // the server is still live after the rejections: a fresh request on
        // the drained queue completes normally
        let g = GenerateReq { id: 99, prompt: prompt_for(99, vocab),
                              max_new_tokens: 4, temperature: Some(0.0),
                              seed: None };
        match cl.run_generate(&g).expect("post-overload generate") {
            GenerateOutcome::Done(r) => assert_eq!(r.tokens.len(), 4),
            GenerateOutcome::Rejected { code, message, .. } => {
                panic!("server dead after overload: {code} ({message})");
            }
        }

        cl.shutdown_server().expect("shutdown");
        let stats = srv.join().expect("server thread").expect("server run");
        assert_eq!(stats.requests_rejected as usize, rejected);
        assert_eq!(stats.counters.requests_completed, done + 1);
    });
}

// ---------------------------------------------------------------------------
// hot-swap bit-identity
// ---------------------------------------------------------------------------

const PRE_ID: u64 = 100;
const PRE_NEW: usize = 12;
const POST_IDS: [usize; 5] = [0, 1, 2, 3, 50];

/// Offline reference tokens for the given `(request id, budget)` pairs,
/// keyed by id.  Prompts/sampling follow `prompt_for` / `sampling_for`, so
/// wire requests built the same way must bit-match.
fn offline_batch(sess: &Session, params: &ParamStore, engine: &Engine,
                 reqs: &[(usize, usize)]) -> BTreeMap<usize, Vec<i32>> {
    let decode_reqs: Vec<DecodeRequest> = reqs.iter()
        .map(|&(k, budget)| {
            let (temperature, seed) = sampling_for(k);
            DecodeRequest { id: k, prompt: prompt_for(k, sess.cfg.vocab),
                            max_new_tokens: budget, temperature, seed }
        })
        .collect();
    let dc = DecodeConfig { max_slots: 3, max_new_tokens: MAX_NEW,
                            temperature: 0.0, seed: 9, arrival_steps: 0.0,
                            prefill_chunk: 0, speculate_k: 0,
                            ..DecodeConfig::default() };
    let (_, done) = run_decode(sess, params, engine, &decode_reqs, &dc)
        .expect("offline decode");
    // completions come back in request order (the assumption the loopback
    // gates above already rely on)
    reqs.iter().map(|&(k, _)| k)
        .zip(done.into_iter().map(|c| c.tokens))
        .collect()
}

/// One hot-swap server lifecycle: start on artifact A, pin a long request
/// to plan A, reload to B mid-stream, check both sides bit-match their
/// offline references, reject a corrupted artifact, and read the counters.
#[allow(clippy::too_many_arguments)]
fn swap_round(sess: &Session, a_manifest: &Path, b_manifest: &Path,
              corrupt_manifest: &Path, corrupt_label: &str,
              speculate_k: usize, offline_pre: &[i32],
              offline_post: &BTreeMap<usize, Vec<i32>>) {
    let vocab = sess.cfg.vocab;
    let bundle = artifact::load(a_manifest).expect("artifact A loads");
    let slot = EngineSlot { params: bundle.params, engine: bundle.engine,
                            drafter: bundle.drafter };
    let cfg = ServerConfig {
        addr: "127.0.0.1:0".into(),
        queue_depth: 16,
        decode: DecodeConfig { max_slots: 3, max_new_tokens: MAX_NEW,
                               temperature: 0.0, seed: 9, arrival_steps: 0.0,
                               prefill_chunk: 0, speculate_k,
                               ..DecodeConfig::default() },
    };
    let (tx, rx) = mpsc::channel::<SocketAddr>();
    std::thread::scope(|s| {
        let cfg = &cfg;
        let srv = s.spawn(move || {
            server::run_swappable(sess, slot, cfg, move |a| {
                tx.send(a).expect("report addr");
            })
        });
        let addr = rx.recv().expect("server bound");
        let mut c1 = Client::connect(addr).expect("connect c1");

        // long-budget request pinned to plan A; reading its first token
        // proves it is admitted and decoding before the reload is posted
        c1.send(&Request::Generate(GenerateReq {
            id: PRE_ID, prompt: prompt_for(PRE_ID as usize, vocab),
            max_new_tokens: PRE_NEW, temperature: Some(0.0), seed: None,
        })).expect("send pre-swap request");
        let mut pre_tokens = Vec::new();
        match c1.next_event().expect("event").expect("stream open") {
            Event::Token { id, index, token } => {
                assert_eq!((id, index), (PRE_ID, 0));
                pre_tokens.push(token);
            }
            other => panic!("expected the first pre-swap token: {other:?}"),
        }

        // reload on a second connection: its reader blocks through the
        // drain, so a successful return means the swap really happened
        let mut c2 = Client::connect(addr).expect("connect c2");
        match c2.reload(b_manifest.to_str().expect("utf8 path"))
            .expect("reload io") {
            ReloadOutcome::Swapped { engine, .. } => {
                assert!(engine.contains("lowrank"),
                        "plan B is low-rank, got `{engine}`");
            }
            ReloadOutcome::Rejected { code, message } => {
                panic!("reload rejected: {code} ({message})");
            }
        }

        // the in-flight request completed entirely on plan A, bit-exactly
        loop {
            match c1.next_event().expect("event").expect("stream open") {
                Event::Token { id, index, token } => {
                    assert_eq!(id, PRE_ID);
                    assert_eq!(index, pre_tokens.len());
                    pre_tokens.push(token);
                }
                Event::Done { id, tokens, .. } => {
                    assert_eq!(id, PRE_ID);
                    assert_eq!(tokens, pre_tokens);
                    break;
                }
                other => panic!("unexpected pre-swap event: {other:?}"),
            }
        }
        assert_eq!(pre_tokens, offline_pre,
                   "in-flight request must finish on plan A (spec_k \
                    {speculate_k})");

        // every post-swap generation bit-matches plan B's offline reference
        for &k in &POST_IDS {
            let (temperature, seed) = sampling_for(k);
            let g = GenerateReq { id: k as u64, prompt: prompt_for(k, vocab),
                                  max_new_tokens: MAX_NEW, temperature, seed };
            match c1.run_generate(&g).expect("post-swap generate") {
                GenerateOutcome::Done(r) => {
                    assert_eq!(&r.tokens, &offline_post[&k],
                               "request {k} after swap must bit-match a \
                                fresh server on plan B");
                }
                GenerateOutcome::Rejected { code, message, .. } => {
                    panic!("request {k} rejected: {code} ({message})");
                }
            }
            if k == POST_IDS[2] {
                // mid-sequence: a corrupted artifact is rejected with a
                // structured error naming the chunk, and B keeps serving
                match c2.reload(corrupt_manifest.to_str().expect("utf8"))
                    .expect("reload io") {
                    ReloadOutcome::Rejected { code, message } => {
                        assert_eq!(code, ERR_RELOAD_FAILED);
                        assert!(message.contains(corrupt_label),
                                "error must name the bad chunk \
                                 `{corrupt_label}`: {message}");
                    }
                    ReloadOutcome::Swapped { .. } => {
                        panic!("corrupted artifact must not swap in");
                    }
                }
            }
        }

        // the swap (and the rejected one) are visible in the wire counters
        let snap = c2.metrics().expect("metrics");
        let counters = snap.get("counters").expect("counters object");
        assert_eq!(counters.usize_or("artifact.swaps", 0), 1);
        assert_eq!(counters.usize_or("artifact.reload_failures", 0), 1);

        c1.shutdown_server().expect("shutdown");
        let stats = srv.join().expect("server thread").expect("server run");
        assert_eq!(stats.counters.plan_swaps, 1);
        assert!(stats.engine.starts_with("dense"),
                "ServerStats reports the initial slot, got {}", stats.engine);
    });
}

#[test]
fn artifact_hot_swap_bitmatches_fresh_plans() {
    let rt = Runtime::load_default().unwrap();
    let sess = Session::new(&rt, "tiny");
    let mut rng = Rng::new(0x5A4B);
    let params = init_params(&sess.cfg, &mut rng);
    let drafter = Engine::Lowrank {
        tag: "60".into(),
        factors: synthetic_factors(&sess, "60", &mut rng),
    };
    let engine_b = Engine::Lowrank {
        tag: "60".into(),
        factors: synthetic_factors(&sess, "60", &mut rng),
    };

    // plans A (dense) and B (low-rank) share one content-addressed store
    let root = std::env::temp_dir()
        .join(format!("zs_swap_store_{}", std::process::id()));
    std::fs::remove_dir_all(&root).ok();
    let a_manifest = artifact::pack(&sess.cfg, &params, &Engine::Dense,
                                    Some(&drafter), &root, "plan-a")
        .expect("pack A");
    let b_manifest = artifact::pack(&sess.cfg, &params, &engine_b,
                                    Some(&drafter), &root, "plan-b")
        .expect("pack B");

    // the corrupt artifact lives in its OWN store: flipping one of its
    // chunks must not damage A's or B's (content-shared) chunks
    let root_c = std::env::temp_dir()
        .join(format!("zs_swap_corrupt_{}", std::process::id()));
    std::fs::remove_dir_all(&root_c).ok();
    let c_manifest = artifact::pack(&sess.cfg, &params, &engine_b,
                                    Some(&drafter), &root_c, "plan-c")
        .expect("pack C");
    let m = read_manifest_file(&c_manifest).expect("manifest C");
    let store_c = ChunkStore::open(&root_c).expect("store C");
    let victim = m.records.iter()
        .find(|r| r.class == ChunkClass::Param)
        .expect("a param record");
    let path = store_c.chunk_path(&victim.id);
    let mut bytes = std::fs::read(&path).expect("chunk bytes");
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x01;
    std::fs::write(&path, bytes).expect("corrupt chunk");

    for threads in [1usize, 4] {
        exec::set_threads(threads);
        let offline_pre = offline_batch(&sess, &params, &Engine::Dense,
                                        &[(PRE_ID as usize, PRE_NEW)])
            .remove(&(PRE_ID as usize))
            .expect("pre reference");
        let offline_post = offline_batch(&sess, &params, &engine_b,
                                         &POST_IDS.map(|k| (k, MAX_NEW)));
        for speculate_k in [0usize, 2] {
            swap_round(&sess, &a_manifest, &b_manifest, &c_manifest,
                       &victim.label, speculate_k, &offline_pre,
                       &offline_post);
        }
    }
    exec::set_threads(0);
    std::fs::remove_dir_all(&root).ok();
    std::fs::remove_dir_all(&root_c).ok();
}
