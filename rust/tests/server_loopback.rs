//! Network serving gates.
//!
//! 1. **Loopback bit-match** — N concurrent TCP clients stream generations
//!    that reproduce the offline `decode::run_decode` tokens BIT-EXACTLY
//!    for the same prompts / temperatures / seeds, on both the dense and a
//!    low-rank engine, at thread counts {1, 4} and prefill chunk sizes
//!    {1, 3, whole-prompt} (the offline reference always runs whole-prompt,
//!    so the sweep also proves chunk-size invariance over the wire).
//!    Everything thread-global lives in one test function
//!    (`exec::set_threads` is process-wide, the `parallel_equiv.rs`
//!    pattern).  ci.sh re-runs this gate under `PALLAS_NO_SIMD=1`, so the
//!    wire bit-match holds on both kernel backends (backend bit-identity
//!    itself is `rust/tests/kernel_equiv.rs`'s job; `force_backend` is
//!    process-global and never flipped here).
//! 2. **Backpressure** — with one slot busy and the admission queue full,
//!    further requests get a structured `overloaded` reply (never a silent
//!    drop), every admitted request completes exactly once, and the server
//!    keeps serving afterwards.

use std::collections::BTreeMap;
use std::net::SocketAddr;
use std::sync::mpsc;

use zs_svd::decode::{run_decode, DecodeConfig, DecodeRequest};
use zs_svd::exec;
use zs_svd::model::init::init_params;
use zs_svd::model::ParamStore;
use zs_svd::runtime::session::Session;
use zs_svd::runtime::Runtime;
use zs_svd::serve::Engine;
use zs_svd::server::protocol::{Event, ERR_BAD_REQUEST, ERR_OVERLOADED};
use zs_svd::server::{self, Client, GenerateOutcome, GenerateReq, Request,
                     ServerConfig};
use zs_svd::tensor::Mat;
use zs_svd::util::rng::Rng;

const CLIENTS: usize = 4;
const PER_CLIENT: usize = 2;
const PROMPT_LEN: usize = 8;
const MAX_NEW: usize = 6;

/// Uniform-rank random factors matching the artifact ranks of `tag` — valid
/// for both the prefill and decode low-rank entry points.
fn synthetic_factors(sess: &Session, tag: &str, rng: &mut Rng)
                     -> BTreeMap<String, (Mat, Mat)> {
    let lm = sess.cfg.lowrank.get(tag).expect("artifact tag");
    sess.cfg
        .targets
        .iter()
        .map(|t| {
            let (m, n) = t.shape;
            let k = lm.ranks[&t.name];
            (t.name.clone(),
             (Mat::randn(rng, m, k, 0.05), Mat::randn(rng, k, n, 0.05)))
        })
        .collect()
}

/// Deterministic prompt for logical request `k` (same on the wire and in
/// the offline reference).
fn prompt_for(k: usize, vocab: usize) -> Vec<i32> {
    let mut rng = Rng::new(0x5EED ^ (k as u64));
    (0..PROMPT_LEN).map(|_| rng.range(1, vocab) as i32).collect()
}

/// Sampling settings for logical request `k`: alternate greedy and
/// explicit-seed temperature sampling so both paths cross the wire.
fn sampling_for(k: usize) -> (Option<f32>, Option<u64>) {
    if k % 2 == 0 {
        (Some(0.0), None)
    } else {
        (Some(0.7), Some(5000 + k as u64))
    }
}

/// One loopback round: serve `engine` over TCP at the given prefill chunk
/// size (optionally speculating through `drafter` at depth `speculate_k`),
/// drive it with concurrent clients, and return the tokens each logical
/// request streamed.
fn serve_and_collect(sess: &Session, params: &ParamStore, engine: &Engine,
                     drafter: Option<&Engine>, speculate_k: usize,
                     prefill_chunk: usize) -> Vec<(usize, Vec<i32>)> {
    let vocab = sess.cfg.vocab;
    let cfg = ServerConfig {
        addr: "127.0.0.1:0".into(),
        queue_depth: 64,
        decode: DecodeConfig { max_slots: 3, max_new_tokens: MAX_NEW,
                               temperature: 0.0, seed: 9, arrival_steps: 0.0,
                               prefill_chunk, speculate_k,
                               ..DecodeConfig::default() },
    };
    let (tx, rx) = mpsc::channel::<SocketAddr>();
    let mut collected: Vec<(usize, Vec<i32>)> = Vec::new();

    std::thread::scope(|s| {
        let cfg = &cfg;
        let srv = s.spawn(move || {
            server::run(sess, params, engine, drafter, cfg, move |a| {
                tx.send(a).expect("report addr");
            })
        });
        let addr = rx.recv().expect("server bound");

        let handles: Vec<_> = (0..CLIENTS)
            .map(|c| {
                s.spawn(move || {
                    let mut cl = Client::connect(addr).expect("connect");
                    let mut out = Vec::new();
                    for i in 0..PER_CLIENT {
                        let k = c * PER_CLIENT + i;
                        let (temperature, seed) = sampling_for(k);
                        let g = GenerateReq {
                            id: k as u64,
                            prompt: prompt_for(k, vocab),
                            max_new_tokens: MAX_NEW,
                            temperature,
                            seed,
                        };
                        match cl.run_generate(&g).expect("generate") {
                            GenerateOutcome::Done(r) => {
                                // stream discipline is asserted inside
                                // run_generate; record the final tokens
                                assert_eq!(r.tokens.len(), MAX_NEW,
                                           "request {k} budget");
                                assert!(r.latency_ms >= r.ttft_ms);
                                out.push((k, r.tokens));
                            }
                            GenerateOutcome::Rejected { code, message } => {
                                panic!("request {k} rejected: {code} \
                                        ({message})");
                            }
                        }
                    }
                    out
                })
            })
            .collect();
        for h in handles {
            collected.extend(h.join().expect("client thread"));
        }

        let mut cl = Client::connect(addr).expect("connect for shutdown");
        cl.shutdown_server().expect("shutdown");
        let stats = srv.join().expect("server thread").expect("server run");
        assert_eq!(stats.counters.requests_completed, CLIENTS * PER_CLIENT);
        assert_eq!(stats.requests_admitted as usize, CLIENTS * PER_CLIENT);
        assert_eq!(stats.requests_rejected, 0);
        assert_eq!(stats.counters.decode_tokens, CLIENTS * PER_CLIENT * MAX_NEW);
        assert!(stats.e2e.p99 >= stats.e2e.p50);
    });

    collected.sort_by_key(|(k, _)| *k);
    collected
}

/// Offline reference for the same logical requests.
fn offline_reference(sess: &Session, params: &ParamStore, engine: &Engine)
                     -> Vec<Vec<i32>> {
    let reqs: Vec<DecodeRequest> = (0..CLIENTS * PER_CLIENT)
        .map(|k| {
            let (temperature, seed) = sampling_for(k);
            DecodeRequest {
                id: k,
                prompt: prompt_for(k, sess.cfg.vocab),
                max_new_tokens: MAX_NEW,
                temperature,
                seed,
            }
        })
        .collect();
    // whole-prompt prefill: the fixed reference every chunked server run
    // must reproduce
    let dc = DecodeConfig { max_slots: 3, max_new_tokens: MAX_NEW,
                            temperature: 0.0, seed: 9, arrival_steps: 0.0,
                            prefill_chunk: 0, speculate_k: 0,
                            ..DecodeConfig::default() };
    let (_, done) = run_decode(sess, params, engine, &reqs, &dc)
        .expect("offline decode");
    done.into_iter().map(|c| c.tokens).collect()
}

#[test]
fn streamed_tokens_bitmatch_offline_for_both_engines() {
    let rt = Runtime::load_default().unwrap();
    let sess = Session::new(&rt, "tiny");
    let mut rng = Rng::new(0x10BAC);
    let params = init_params(&sess.cfg, &mut rng);
    let factors = synthetic_factors(&sess, "60", &mut rng);
    let lowrank = Engine::Lowrank { tag: "60".into(), factors };

    // chunk sizes {1, 3, whole-prompt}: the offline reference is computed
    // once per engine at whole-prompt prefill, so every chunked server run
    // matching it proves both network parity AND chunk-size invariance
    for threads in [1usize, 4] {
        exec::set_threads(threads);
        for engine in [&Engine::Dense, &lowrank] {
            let offline = offline_reference(&sess, &params, engine);
            for prefill_chunk in [1usize, 3, 0] {
                let served = serve_and_collect(&sess, &params, engine, None,
                                               0, prefill_chunk);
                assert_eq!(served.len(), CLIENTS * PER_CLIENT);
                for (k, tokens) in &served {
                    assert_eq!(tokens, &offline[*k],
                               "engine {} request {k} @ {threads} threads, \
                                prefill chunk {prefill_chunk}: network \
                                generation must bit-match offline",
                               engine.label());
                }
            }
        }
    }
    exec::set_threads(0);
}

#[test]
fn speculative_server_bitmatches_offline_and_reports_acceptance() {
    // a dense server speculating through a low-rank drafter must stream
    // tokens bit-identical to the plain offline dense reference (mixed
    // greedy/temperature clients — temperature slots fall back to plain
    // decode), and the drafter counters must surface in the wire metrics
    let rt = Runtime::load_default().unwrap();
    let sess = Session::new(&rt, "tiny");
    let mut rng = Rng::new(0x5BEC2);
    let params = init_params(&sess.cfg, &mut rng);
    let drafter = Engine::Lowrank {
        tag: "60".into(),
        factors: synthetic_factors(&sess, "60", &mut rng),
    };

    let offline = offline_reference(&sess, &params, &Engine::Dense);
    let served = serve_and_collect(&sess, &params, &Engine::Dense,
                                   Some(&drafter), 2, 3);
    assert_eq!(served.len(), CLIENTS * PER_CLIENT);
    for (k, tokens) in &served {
        assert_eq!(tokens, &offline[*k],
                   "request {k}: speculative server must bit-match the \
                    plain offline dense path");
    }

    // one more round just for the metrics surface
    let cfg = ServerConfig {
        addr: "127.0.0.1:0".into(),
        queue_depth: 8,
        decode: DecodeConfig { max_slots: 2, max_new_tokens: MAX_NEW,
                               temperature: 0.0, seed: 9, arrival_steps: 0.0,
                               prefill_chunk: 0, speculate_k: 2,
                               ..DecodeConfig::default() },
    };
    let (tx, rx) = mpsc::channel::<SocketAddr>();
    std::thread::scope(|s| {
        let cfg = &cfg;
        let sess = &sess;
        let params = &params;
        let drafter = &drafter;
        let srv = s.spawn(move || {
            server::run(sess, params, &Engine::Dense, Some(drafter), cfg,
                        move |a| { tx.send(a).expect("report addr"); })
        });
        let addr = rx.recv().expect("server bound");
        let mut cl = Client::connect(addr).expect("connect");
        let g = GenerateReq { id: 0, prompt: prompt_for(0, sess.cfg.vocab),
                              max_new_tokens: MAX_NEW,
                              temperature: Some(0.0), seed: None };
        match cl.run_generate(&g).expect("generate") {
            GenerateOutcome::Done(r) => {
                assert_eq!(r.tokens, offline[0]);
                assert!(!r.truncated, "nothing was cut short");
            }
            GenerateOutcome::Rejected { code, message } => {
                panic!("rejected: {code} ({message})");
            }
        }
        let snap = cl.metrics().expect("metrics");
        let counters = snap.get("counters").expect("counters object");
        assert!(counters.usize_or("draft_proposed_tokens", 0) >= 1,
                "a greedy generation under speculation must draft");
        let rate = snap.f64_or("draft_acceptance_rate", -1.0);
        assert!((0.0..=1.0).contains(&rate), "rate {rate}");
        cl.shutdown_server().expect("shutdown");
        let stats = srv.join().expect("server thread").expect("server run");
        assert!(stats.counters.drafted_tokens >= 1);
        assert_eq!(stats.engine, "dense+spec-k2");
    });
}

#[test]
fn capacity_truncation_and_zero_budget_over_the_wire() {
    // the two admission/retirement edges the wire must surface: a prompt
    // that fills the KV arena completes with exactly one token and
    // `truncated: true`, and a request whose budget RESOLVES to zero (no
    // client budget, no server default) gets a structured bad_request
    let rt = Runtime::load_default().unwrap();
    let sess = Session::new(&rt, "tiny");
    let mut rng = Rng::new(0xEDF);
    let params = init_params(&sess.cfg, &mut rng);
    let seq = sess.cfg.seq_len;

    let cfg = ServerConfig {
        addr: "127.0.0.1:0".into(),
        queue_depth: 8,
        // a server deliberately configured with NO default budget
        decode: DecodeConfig { max_slots: 1, max_new_tokens: 0,
                               temperature: 0.0, seed: 3, arrival_steps: 0.0,
                               prefill_chunk: 0, speculate_k: 0,
                               ..DecodeConfig::default() },
    };
    let (tx, rx) = mpsc::channel::<SocketAddr>();
    std::thread::scope(|s| {
        let cfg = &cfg;
        let sess = &sess;
        let params = &params;
        let srv = s.spawn(move || {
            server::run(sess, params, &Engine::Dense, None, cfg, move |a| {
                tx.send(a).expect("report addr");
            })
        });
        let addr = rx.recv().expect("server bound");
        let mut cl = Client::connect(addr).expect("connect");

        // arena-filling prompt: one token, flagged truncated
        let g = GenerateReq { id: 0, prompt: vec![1i32; seq],
                              max_new_tokens: 10, temperature: Some(0.0),
                              seed: None };
        match cl.run_generate(&g).expect("generate") {
            GenerateOutcome::Done(r) => {
                assert_eq!(r.tokens.len(), 1,
                           "a full arena leaves room for exactly the \
                            prompt-logits token");
                assert!(r.truncated, "the capacity cut must cross the wire");
            }
            GenerateOutcome::Rejected { code, message } => {
                panic!("rejected: {code} ({message})");
            }
        }

        // zero resolved budget: structured rejection, not a silent 1-token
        // generation (the old scheduler coerced 0 to 1)
        let g = GenerateReq { id: 1, prompt: prompt_for(1, sess.cfg.vocab),
                              max_new_tokens: 0, temperature: Some(0.0),
                              seed: None };
        match cl.run_generate(&g).expect("generate") {
            GenerateOutcome::Rejected { code, .. } => {
                assert_eq!(code, ERR_BAD_REQUEST);
            }
            GenerateOutcome::Done(r) => {
                panic!("zero budget must be rejected, got {} tokens",
                       r.tokens.len());
            }
        }

        cl.shutdown_server().expect("shutdown");
        let stats = srv.join().expect("server thread").expect("server run");
        assert_eq!(stats.counters.requests_completed, 1);
    });
}

#[test]
fn queue_full_gets_overloaded_and_server_stays_live() {
    let rt = Runtime::load_default().unwrap();
    let sess = Session::new(&rt, "tiny");
    let mut rng = Rng::new(0xBACC);
    let params = init_params(&sess.cfg, &mut rng);
    let vocab = sess.cfg.vocab;

    // one slot + depth-1 queue: at most 2 requests in the system; a fast
    // burst of 5 must see at least one structured rejection
    const BURST: usize = 5;
    let cfg = ServerConfig {
        addr: "127.0.0.1:0".into(),
        queue_depth: 1,
        decode: DecodeConfig { max_slots: 1, max_new_tokens: 24,
                               temperature: 0.0, seed: 3, arrival_steps: 0.0,
                               prefill_chunk: 0, speculate_k: 0,
                               ..DecodeConfig::default() },
    };
    let (tx, rx) = mpsc::channel::<SocketAddr>();

    std::thread::scope(|s| {
        let cfg = &cfg;
        let sess = &sess;
        let params = &params;
        let srv = s.spawn(move || {
            server::run(sess, params, &Engine::Dense, None, cfg, move |a| {
                tx.send(a).expect("report addr");
            })
        });
        let addr = rx.recv().expect("server bound");

        let mut cl = Client::connect(addr).expect("connect");
        // pipeline the whole burst without reading replies, so the queue
        // sees the requests back-to-back while slot 0 is busy generating
        for k in 0..BURST {
            cl.send(&Request::Generate(GenerateReq {
                id: k as u64,
                prompt: prompt_for(k, vocab),
                max_new_tokens: 24,
                temperature: Some(0.0),
                seed: None,
            }))
            .expect("send");
        }

        // collect exactly one terminal outcome per request id
        let mut outcomes: BTreeMap<u64, &'static str> = BTreeMap::new();
        let mut tokens_seen: BTreeMap<u64, usize> = BTreeMap::new();
        while outcomes.len() < BURST {
            match cl.next_event().expect("event").expect("open stream") {
                Event::Token { id, index, token } => {
                    let n = tokens_seen.entry(id).or_insert(0);
                    assert_eq!(index, *n, "sequential stream for {id}");
                    *n += 1;
                    assert!(token >= 0 && (token as usize) < vocab);
                    assert!(!outcomes.contains_key(&id),
                            "token after terminal event for {id}");
                }
                Event::Done { id, tokens, .. } => {
                    assert_eq!(tokens.len(), tokens_seen.get(&id).copied()
                               .unwrap_or(0), "done matches stream for {id}");
                    let prev = outcomes.insert(id, "done");
                    assert!(prev.is_none(), "request {id} completed twice");
                }
                Event::Error { id, code, .. } => {
                    let id = id.expect("rejections carry the request id");
                    assert_eq!(code, ERR_OVERLOADED,
                               "only overload rejections expected");
                    let prev = outcomes.insert(id, "overloaded");
                    assert!(prev.is_none(), "request {id} rejected twice");
                }
                other => panic!("unexpected event: {other:?}"),
            }
        }
        let done = outcomes.values().filter(|v| **v == "done").count();
        let rejected = outcomes.values().filter(|v| **v == "overloaded").count();
        assert_eq!(done + rejected, BURST);
        assert!(rejected >= 1, "a depth-1 queue must reject part of a \
                                5-deep burst (done {done})");
        assert!(done >= 1, "the slot must have served part of the burst");
        // the first request is admitted before the queue can fill
        assert_eq!(outcomes.get(&0).copied(), Some("done"));

        // the server is still live after the rejections: a fresh request on
        // the drained queue completes normally
        let g = GenerateReq { id: 99, prompt: prompt_for(99, vocab),
                              max_new_tokens: 4, temperature: Some(0.0),
                              seed: None };
        match cl.run_generate(&g).expect("post-overload generate") {
            GenerateOutcome::Done(r) => assert_eq!(r.tokens.len(), 4),
            GenerateOutcome::Rejected { code, message } => {
                panic!("server dead after overload: {code} ({message})");
            }
        }

        cl.shutdown_server().expect("shutdown");
        let stats = srv.join().expect("server thread").expect("server run");
        assert_eq!(stats.requests_rejected as usize, rejected);
        assert_eq!(stats.counters.requests_completed, done + 1);
    });
}
