//! Pipeline-level integration: compression quality gates on a (briefly)
//! trained model.  These are the "does the paper's method actually behave
//! like the paper" tests — ZS-SVD must beat plain SVD, corrections must not
//! hurt, the zero-shot scorer must beat chance after training, and the plan
//! accounting must hit its budget.

use std::path::PathBuf;

use zs_svd::compress::{calibrate, compress_zs, Costing, Strategy, ZsOpts};
use zs_svd::coordinator::{self, Method};
use zs_svd::data::{self, TaskFamily};
use zs_svd::eval::{self, EvalSpec};
use zs_svd::runtime::session::Session;
use zs_svd::runtime::Runtime;
use zs_svd::trainer::{ensure_trained, TrainConfig};

/// Shared pretrained context (300 steps ≈ 80 s cold, checkpoint-cached —
/// the same checkpoint the bench harnesses use).
fn prepared(rt: &Runtime) -> (Session<'_>, zs_svd::model::ParamStore,
                              data::World, data::Corpus) {
    let session = Session::new(rt, "tiny");
    let world = data::default_world();
    let corpus = data::training_corpus("llama", &world);
    let tc = TrainConfig { steps: 300, lr: 3e-3, warmup: 30, seed: 7,
                           log_every: 1000 };
    let ckpt_dir = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts").join("ckpts");
    let params = ensure_trained(&session, &corpus, "llama", &tc, &ckpt_dir)
        .expect("train");
    (session, params, world, corpus)
}

#[test]
fn zs_svd_beats_plain_svd_under_aggressive_compression() {
    let rt = Runtime::load_default().unwrap();
    let (session, params, _world, corpus) = prepared(&rt);
    let calib = calibrate(&session, &params, &corpus, 2, 0xCA11B).unwrap();
    let ratio = 0.15;

    let zs = compress_zs(&session, &params, &calib, &ZsOpts::new(ratio)).unwrap();
    let plain = zs_svd::compress::baselines::svd_plain(&session, &params, ratio);

    let ppl = |plan: &zs_svd::compress::CompressionPlan| {
        eval::perplexity(&session, &plan.apply(&params), &corpus, 2).unwrap()
    };
    let p_zs = ppl(&zs);
    let p_plain = ppl(&plain);
    assert!(p_zs < p_plain,
            "zs-svd ({p_zs:.3}) should beat plain svd ({p_plain:.3}) at {ratio}");
}

#[test]
fn whitened_beats_raw_truncation() {
    let rt = Runtime::load_default().unwrap();
    let (session, params, _world, corpus) = prepared(&rt);
    let calib = calibrate(&session, &params, &corpus, 2, 0xCA11B).unwrap();
    let ratio = 0.15;
    let svdllm = zs_svd::compress::baselines::svdllm(&session, &params, &calib, ratio);
    let plain = zs_svd::compress::baselines::svd_plain(&session, &params, ratio);
    let ppl = |plan: &zs_svd::compress::CompressionPlan| {
        eval::perplexity(&session, &plan.apply(&params), &corpus, 2).unwrap()
    };
    assert!(ppl(&svdllm) < ppl(&plain), "whitening must help");
}

#[test]
fn correction_does_not_hurt() {
    let rt = Runtime::load_default().unwrap();
    let (session, params, _world, corpus) = prepared(&rt);
    let calib = calibrate(&session, &params, &corpus, 2, 0xCA11B).unwrap();
    let ratio = 0.15;
    let plain = compress_zs(&session, &params, &calib, &ZsOpts::new(ratio)).unwrap();
    let fixed = compress_zs(&session, &params, &calib,
                            &ZsOpts { correction_iters: 1, ..ZsOpts::new(ratio) })
        .unwrap();
    let ppl = |plan: &zs_svd::compress::CompressionPlan| {
        eval::perplexity(&session, &plan.apply(&params), &corpus, 2).unwrap()
    };
    let (p0, p1) = (ppl(&plain), ppl(&fixed));
    assert!(p1 <= p0 * 1.05, "1x correction hurt badly: {p0:.3} -> {p1:.3}");
}

#[test]
fn budget_hit_across_costings() {
    let rt = Runtime::load_default().unwrap();
    let (session, params, _world, corpus) = prepared(&rt);
    let calib = calibrate(&session, &params, &corpus, 2, 0xCA11B).unwrap();
    for (ratio, costing) in [(0.35, Costing::Standard), (0.35, Costing::Remap),
                             (0.15, Costing::Standard)] {
        let plan = compress_zs(&session, &params, &calib,
                               &ZsOpts { costing, ..ZsOpts::new(ratio) }).unwrap();
        let achieved = plan.achieved_ratio();
        assert!(achieved <= ratio + 0.02,
                "{costing:?}@{ratio}: achieved {achieved}");
        // heterogeneous ranks should actually be heterogeneous
        let ranks = plan.ranks();
        let distinct: std::collections::BTreeSet<usize> =
            ranks.values().copied().collect();
        assert!(distinct.len() > 2, "ranks suspiciously uniform: {distinct:?}");
    }
}

#[test]
fn hq_matches_footprint_of_plain_at_double_depth() {
    let rt = Runtime::load_default().unwrap();
    let (session, params, _world, corpus) = prepared(&rt);
    let calib = calibrate(&session, &params, &corpus, 2, 0xCA11B).unwrap();
    let ratio = 0.2;
    let hq = compress_zs(&session, &params, &calib,
                         &ZsOpts { hq: true, ..ZsOpts::new(ratio) }).unwrap();
    // HQ = selection at 2·ratio retention, then int8 => footprint ≈ ratio
    assert!((hq.achieved_ratio() - ratio).abs() < 0.03,
            "hq achieved {}", hq.achieved_ratio());
}

#[test]
fn zeroshot_beats_chance_after_training() {
    let rt = Runtime::load_default().unwrap();
    let (session, params, world, _corpus) = prepared(&rt);
    // arc_e (2 options => chance 0.5) is the most learnable family
    let instances = data::generate_set(&world, TaskFamily::ArcESyn, 40, 0xE1);
    let acc = eval::score_tasks(&session, &params, &instances).unwrap();
    assert!(acc > 0.6, "arc_e-syn accuracy {acc} not above chance");
    // mathqa (4 options => chance 0.25)
    let math = data::generate_set(&world, TaskFamily::MathqaSyn, 40, 0xE1);
    let macc = eval::score_tasks(&session, &params, &math).unwrap();
    assert!(macc > 0.3, "mathqa-syn accuracy {macc} at chance");
}

#[test]
fn selection_strategies_rank_as_in_table6() {
    // zero-sum must beat the loss-blind sigma rule at aggressive ratios
    let rt = Runtime::load_default().unwrap();
    let (session, params, _world, corpus) = prepared(&rt);
    let calib = calibrate(&session, &params, &corpus, 2, 0xCA11B).unwrap();
    let ratio = 0.15;
    let ppl_of = |strategy| {
        let plan = compress_zs(&session, &params, &calib,
                               &ZsOpts { strategy, ..ZsOpts::new(ratio) })
            .unwrap();
        eval::perplexity(&session, &plan.apply(&params), &corpus, 2).unwrap()
    };
    let zs = ppl_of(Strategy::ZeroSum);
    let most_neg_unordered = ppl_of(Strategy::MostNegative { per_w_order: false });
    assert!(zs < most_neg_unordered,
            "zero-sum {zs:.3} vs most-neg-unordered {most_neg_unordered:.3}");
}

#[test]
fn coordinator_dispatch_covers_all_methods() {
    let rt = Runtime::load_default().unwrap();
    let mut cfg = zs_svd::config::ExperimentConfig::default();
    cfg.train_steps = 300;
    cfg.calib_batches = 2;
    let p = coordinator::prepare(&rt, &cfg).unwrap();
    let ratio = 0.3;
    for m in [Method::Svd, Method::Fwsvd, Method::Asvd, Method::SvdLlm,
              Method::DobiSim { sweeps: 1 }, Method::zs(ratio),
              Method::zs_remap(ratio),
              Method::Prune(zs_svd::compress::baselines::PruneScore::WandaSp),
              Method::SliceGpt] {
        let plan = coordinator::run_method(&p, &m, ratio).unwrap();
        assert!(!plan.targets.is_empty(), "{}", plan.method);
        let spec = EvalSpec { ppl_batches: 1, instances_per_family: 4,
                              task_seed: 1 };
        let r = coordinator::evaluate_plan(&p, Some(&plan), &spec).unwrap();
        assert!(r.ppl_of("wiki-syn").is_finite(), "{}", plan.method);
    }
}
