//! Decode parity gate + continuous-batching contract.
//!
//! The parity half proves the headline invariant of the decode subsystem:
//! for a fixed prefix, the KV-cached incremental path reproduces the full
//! forward's last-token logits BIT-EXACTLY, for threads {1, 2, 4}, on both
//! the dense and the low-rank engines.  Everything thread-global lives in
//! ONE test function (`exec::set_threads` is process-wide, same pattern as
//! `parallel_equiv.rs`); the scheduler tests rely only on results that are
//! thread-count independent by construction.

use std::collections::BTreeMap;

use zs_svd::decode::{run_decode, synth_requests, DecodeConfig, DecodeRequest};
use zs_svd::exec;
use zs_svd::model::init::init_params;
use zs_svd::runtime::session::Session;
use zs_svd::runtime::Runtime;
use zs_svd::serve::Engine;
use zs_svd::tensor::Mat;
use zs_svd::util::rng::Rng;

/// Uniform-rank random factors matching the artifact ranks of `tag` — valid
/// for both `lowrank_fwd` and `lowrank_decode_step`.
fn synthetic_factors(sess: &Session, tag: &str, rng: &mut Rng)
                     -> BTreeMap<String, (Mat, Mat)> {
    let lm = sess.cfg.lowrank.get(tag).expect("artifact tag");
    sess.cfg
        .targets
        .iter()
        .map(|t| {
            let (m, n) = t.shape;
            let k = lm.ranks[&t.name];
            (t.name.clone(),
             (Mat::randn(rng, m, k, 0.05), Mat::randn(rng, k, n, 0.05)))
        })
        .collect()
}

#[test]
fn decode_bitmatches_forward_for_all_thread_counts() {
    let rt = Runtime::load_default().unwrap();
    let sess = Session::new(&rt, "tiny");
    let mut rng = Rng::new(0xDECD);
    let params = init_params(&sess.cfg, &mut rng);
    let seq = sess.cfg.seq_len;
    let tag = "60";
    let factors = synthetic_factors(&sess, tag, &mut rng);

    // one fixed (1, T+1) token row; the full forward sees all of it, the
    // decode path replays prefixes of it
    let tokens: Vec<i32> = (0..seq + 1)
        .map(|_| rng.range(1, sess.cfg.vocab) as i32)
        .collect();
    let full = zs_svd::tensor::IntTensor::from_vec(&[1, seq + 1], tokens.clone());

    for t in [1usize, 2, 4] {
        exec::set_threads(t);
        let (_, dense_logits) = sess.fwd(&params, &full).unwrap();
        let (_, lr_logits) = sess.lowrank_fwd(tag, &params, &factors, &full)
            .unwrap();

        let mut dense_cache = sess.new_kv_cache();
        let mut lr_cache = sess.new_kv_cache();
        for pos in 0..seq {
            let d_step = sess.decode_step(&params, &mut dense_cache, tokens[pos])
                .unwrap();
            let l_step = sess
                .lowrank_decode_step(tag, &params, &factors, &mut lr_cache,
                                     tokens[pos])
                .unwrap();
            // causality: forward row `pos` only sees tokens 0..=pos, so the
            // step logits must reproduce it bit for bit
            let v = sess.cfg.vocab;
            assert_eq!(&d_step.data[..], &dense_logits.data[pos * v..(pos + 1) * v],
                       "dense decode != forward at pos {pos}, {t} threads");
            assert_eq!(&l_step.data[..], &lr_logits.data[pos * v..(pos + 1) * v],
                       "lowrank decode != forward at pos {pos}, {t} threads");
        }
        assert_eq!(dense_cache.len, seq);
        assert_eq!(lr_cache.len, seq);
    }
    exec::set_threads(0);
}

#[test]
fn decode_matches_forward_on_opt_arch() {
    // learned positions + LayerNorm + GELU take a different step path than
    // llama; the parity invariant must hold there too (thread-count
    // independence is already guaranteed by the kernels, so no sweep)
    let rt = Runtime::load_default().unwrap();
    let sess = Session::new(&rt, "opt_tiny");
    let mut rng = Rng::new(0x0F7);
    let params = init_params(&sess.cfg, &mut rng);
    let seq = sess.cfg.seq_len;
    let tokens: Vec<i32> = (0..seq + 1)
        .map(|_| rng.range(1, sess.cfg.vocab) as i32)
        .collect();
    let full = zs_svd::tensor::IntTensor::from_vec(&[1, seq + 1], tokens.clone());
    let (_, logits) = sess.fwd(&params, &full).unwrap();
    let mut cache = sess.new_kv_cache();
    let v = sess.cfg.vocab;
    for pos in 0..seq {
        let step = sess.decode_step(&params, &mut cache, tokens[pos]).unwrap();
        assert_eq!(&step.data[..], &logits.data[pos * v..(pos + 1) * v],
                   "opt decode != forward at pos {pos}");
    }
}

#[test]
fn continuous_batching_serves_every_request_exactly_once() {
    let rt = Runtime::load_default().unwrap();
    let sess = Session::new(&rt, "tiny");
    let mut rng = Rng::new(0xBA7);
    let params = init_params(&sess.cfg, &mut rng);

    // saturating arrivals: 9 requests into 3 slots, all eligible at t=0
    let cfg = DecodeConfig { max_slots: 3, max_new_tokens: 4, temperature: 0.0,
                             seed: 5, arrival_steps: 0.0 };
    let reqs = synth_requests(&sess.cfg, 9, 12, 4, 0xFEED);
    let (stats, done) = run_decode(&sess, &params, &Engine::Dense, &reqs, &cfg)
        .unwrap();

    assert_eq!(stats.requests, 9);
    assert_eq!(done.len(), 9);
    let ids: Vec<usize> = done.iter().map(|c| c.id).collect();
    assert_eq!(ids, (0..9).collect::<Vec<_>>(), "each id exactly once");
    for c in &done {
        assert_eq!(c.tokens.len(), 4, "request {} budget", c.id);
        assert!(c.tokens.iter().all(|&t| t >= 0
                    && (t as usize) < sess.cfg.vocab));
        assert!(c.latency_ms >= c.ttft_ms);
    }
    assert_eq!(stats.decode_tokens, 9 * 4);
    assert_eq!(stats.prefill_tokens, 9 * 12);
    assert!(stats.decode_tok_per_sec > 0.0);
    assert!(stats.latency.p95 >= stats.latency.p50);
    assert!(stats.latency.p99 >= stats.latency.p95);
    assert!(stats.kv_bytes_per_slot > 0);
}

#[test]
fn generation_is_reproducible_and_slot_count_invariant() {
    let rt = Runtime::load_default().unwrap();
    let sess = Session::new(&rt, "tiny");
    let mut rng = Rng::new(0x9E4);
    let params = init_params(&sess.cfg, &mut rng);
    let reqs = synth_requests(&sess.cfg, 5, 8, 6, 0xAB);

    let run = |slots: usize, temperature: f32| {
        let cfg = DecodeConfig { max_slots: slots, max_new_tokens: 6,
                                 temperature, seed: 11, arrival_steps: 0.0 };
        let (_, done) = run_decode(&sess, &params, &Engine::Dense, &reqs, &cfg)
            .unwrap();
        done.into_iter().map(|c| c.tokens).collect::<Vec<_>>()
    };

    // greedy and temperature sampling are both deterministic per request,
    // so tokens cannot depend on the slot count (scheduling) at all
    assert_eq!(run(1, 0.0), run(4, 0.0));
    assert_eq!(run(2, 0.8), run(3, 0.8));
    // and repeated runs reproduce exactly
    assert_eq!(run(2, 0.8), run(2, 0.8));
}

#[test]
fn generation_respects_kv_capacity() {
    let rt = Runtime::load_default().unwrap();
    let sess = Session::new(&rt, "tiny");
    let mut rng = Rng::new(0xCAFE);
    let params = init_params(&sess.cfg, &mut rng);
    let seq = sess.cfg.seq_len;

    // prompt nearly fills the arena: the budget of 10 must be cut short
    let reqs = vec![DecodeRequest::new(0, vec![1i32; seq - 2], 10)];
    let cfg = DecodeConfig { max_slots: 1, max_new_tokens: 10,
                             temperature: 0.0, seed: 1, arrival_steps: 0.0 };
    let (stats, done) = run_decode(&sess, &params, &Engine::Dense, &reqs, &cfg)
        .unwrap();
    // prefill leaves 2 free positions; each decode step consumes one, and
    // the token sampled from the arena-filling step still counts
    assert_eq!(done[0].tokens.len(), 3);
    assert_eq!(stats.decode_tokens, 3);
}
