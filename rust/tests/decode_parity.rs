//! Decode parity gate + continuous-batching contract.
//!
//! The parity half proves the headline invariants of the decode subsystem:
//!
//! * for a fixed prefix, the KV-cached incremental path reproduces the full
//!   forward's last-token logits BIT-EXACTLY, for threads {1, 2, 4}, on
//!   both the dense and the low-rank engines;
//! * the batched `decode_batch` kernel reproduces the token-at-a-time
//!   `decode_step` reference BIT-EXACTLY for every prefill chunking
//!   (chunk sizes {1, 3, full} leave identical KV contents and logits) and
//!   for every across-slot batch composition, at threads {1, 4};
//! * the verify-mode path (`decode_batch_modes`, `LogitsMode::All`)
//!   returns the stepwise reference row at EVERY run position, and
//!   speculative self-decode (low-rank drafter, dense target) generates
//!   bit-identical tokens to plain greedy decode for K ∈ {1, 2, 4} at
//!   threads {1, 4} — including at the KV-capacity boundary, where the
//!   rollback arithmetic is tightest.
//!
//! Everything thread-global lives in ONE test function per sweep
//! (`exec::set_threads` is process-wide, same pattern as
//! `parallel_equiv.rs`); the scheduler tests rely only on results that are
//! thread-count independent by construction.
//!
//! Kernel backends: ci.sh re-runs this whole gate under `PALLAS_NO_SIMD=1`,
//! so every parity invariant is proven on BOTH the SIMD and the portable
//! backend (the backends themselves are bit-identical — see
//! `rust/tests/kernel_equiv.rs`, which also cross-checks decode logits
//! across backends directly).  `force_backend` is deliberately not flipped
//! here: it is process-global, and the tests in this binary run
//! concurrently.

use std::collections::BTreeMap;

use zs_svd::decode::{run_decode, run_decode_speculative, synth_requests,
                     DecodeConfig, DecodeRequest, KvCache};
use zs_svd::exec;
use zs_svd::model::init::init_params;
use zs_svd::runtime::native::LogitsMode;
use zs_svd::runtime::session::Session;
use zs_svd::runtime::Runtime;
use zs_svd::serve::Engine;
use zs_svd::tensor::Mat;
use zs_svd::util::rng::Rng;

/// Uniform-rank random factors matching the artifact ranks of `tag` — valid
/// for both `lowrank_fwd` and `lowrank_decode_step`.
fn synthetic_factors(sess: &Session, tag: &str, rng: &mut Rng)
                     -> BTreeMap<String, (Mat, Mat)> {
    let lm = sess.cfg.lowrank.get(tag).expect("artifact tag");
    sess.cfg
        .targets
        .iter()
        .map(|t| {
            let (m, n) = t.shape;
            let k = lm.ranks[&t.name];
            (t.name.clone(),
             (Mat::randn(rng, m, k, 0.05), Mat::randn(rng, k, n, 0.05)))
        })
        .collect()
}

#[test]
fn decode_bitmatches_forward_for_all_thread_counts() {
    let rt = Runtime::load_default().unwrap();
    let sess = Session::new(&rt, "tiny");
    let mut rng = Rng::new(0xDECD);
    let params = init_params(&sess.cfg, &mut rng);
    let seq = sess.cfg.seq_len;
    let tag = "60";
    let factors = synthetic_factors(&sess, tag, &mut rng);

    // one fixed (1, T+1) token row; the full forward sees all of it, the
    // decode path replays prefixes of it
    let tokens: Vec<i32> = (0..seq + 1)
        .map(|_| rng.range(1, sess.cfg.vocab) as i32)
        .collect();
    let full = zs_svd::tensor::IntTensor::from_vec(&[1, seq + 1], tokens.clone());

    for t in [1usize, 2, 4] {
        exec::set_threads(t);
        let (_, dense_logits) = sess.fwd(&params, &full).unwrap();
        let (_, lr_logits) = sess.lowrank_fwd(tag, &params, &factors, &full)
            .unwrap();

        let mut dense_cache = sess.new_kv_cache();
        let mut lr_cache = sess.new_kv_cache();
        for pos in 0..seq {
            let d_step = sess.decode_step(&params, &mut dense_cache, tokens[pos])
                .unwrap();
            let l_step = sess
                .lowrank_decode_step(tag, &params, &factors, &mut lr_cache,
                                     tokens[pos])
                .unwrap();
            // causality: forward row `pos` only sees tokens 0..=pos, so the
            // step logits must reproduce it bit for bit
            let v = sess.cfg.vocab;
            assert_eq!(&d_step.data[..], &dense_logits.data[pos * v..(pos + 1) * v],
                       "dense decode != forward at pos {pos}, {t} threads");
            assert_eq!(&l_step.data[..], &lr_logits.data[pos * v..(pos + 1) * v],
                       "lowrank decode != forward at pos {pos}, {t} threads");
        }
        assert_eq!(dense_cache.len, seq);
        assert_eq!(lr_cache.len, seq);
    }
    exec::set_threads(0);
}

#[test]
fn decode_matches_forward_on_opt_arch() {
    // learned positions + LayerNorm + GELU take a different step path than
    // llama; the parity invariant must hold there too (thread-count
    // independence is already guaranteed by the kernels, so no sweep)
    let rt = Runtime::load_default().unwrap();
    let sess = Session::new(&rt, "opt_tiny");
    let mut rng = Rng::new(0x0F7);
    let params = init_params(&sess.cfg, &mut rng);
    let seq = sess.cfg.seq_len;
    let tokens: Vec<i32> = (0..seq + 1)
        .map(|_| rng.range(1, sess.cfg.vocab) as i32)
        .collect();
    let full = zs_svd::tensor::IntTensor::from_vec(&[1, seq + 1], tokens.clone());
    let (_, logits) = sess.fwd(&params, &full).unwrap();
    let mut cache = sess.new_kv_cache();
    let v = sess.cfg.vocab;
    for pos in 0..seq {
        let step = sess.decode_step(&params, &mut cache, tokens[pos]).unwrap();
        assert_eq!(&step.data[..], &logits.data[pos * v..(pos + 1) * v],
                   "opt decode != forward at pos {pos}");
    }
}

#[test]
fn chunked_prefill_bitmatches_token_at_a_time() {
    let rt = Runtime::load_default().unwrap();
    let sess = Session::new(&rt, "tiny");
    let mut rng = Rng::new(0xC4A);
    let params = init_params(&sess.cfg, &mut rng);
    let tag = "60";
    let factors = synthetic_factors(&sess, tag, &mut rng);

    // prompt length indivisible by 3 so the last chunk is ragged
    let plen = 11usize;
    let prompt: Vec<i32> = (0..plen)
        .map(|_| rng.range(1, sess.cfg.vocab) as i32)
        .collect();

    for threads in [1usize, 4] {
        exec::set_threads(threads);
        // token-at-a-time reference through the incremental step kernel
        let mut ref_dense = sess.new_kv_cache();
        let mut ref_lr = sess.new_kv_cache();
        let mut ref_dense_logits = None;
        let mut ref_lr_logits = None;
        for &t in &prompt {
            ref_dense_logits =
                Some(sess.decode_step(&params, &mut ref_dense, t).unwrap());
            ref_lr_logits = Some(
                sess.lowrank_decode_step(tag, &params, &factors, &mut ref_lr, t)
                    .unwrap(),
            );
        }

        for chunk in [1usize, 3, plen] {
            let mut dense_cache = sess.new_kv_cache();
            let mut lr_cache = sess.new_kv_cache();
            let mut dense_logits = None;
            let mut lr_logits = None;
            let mut pos = 0usize;
            while pos < plen {
                let end = (pos + chunk).min(plen);
                // logits are requested only for the prompt-completing
                // chunk, exactly as the scheduler drives prefill
                let last = end == plen;
                {
                    let mut seqs =
                        vec![(&mut dense_cache, &prompt[pos..end])];
                    let got = sess.decode_batch(&params, &mut seqs, &[last])
                        .unwrap()
                        .remove(0);
                    assert_eq!(got.is_some(), last,
                               "logits exactly when requested");
                    if last {
                        dense_logits = got;
                    }
                }
                {
                    let mut seqs = vec![(&mut lr_cache, &prompt[pos..end])];
                    let got = sess
                        .lowrank_decode_batch(tag, &params, &factors,
                                              &mut seqs, &[last])
                        .unwrap()
                        .remove(0);
                    assert_eq!(got.is_some(), last,
                               "logits exactly when requested");
                    if last {
                        lr_logits = got;
                    }
                }
                pos = end;
            }
            assert_eq!(dense_cache.len, plen);
            assert_eq!(lr_cache.len, plen);
            // the final chunk's logits are the last prompt position's
            assert_eq!(dense_logits.unwrap().data,
                       ref_dense_logits.as_ref().unwrap().data,
                       "dense chunk {chunk} logits @ {threads} threads");
            assert_eq!(lr_logits.unwrap().data,
                       ref_lr_logits.as_ref().unwrap().data,
                       "lowrank chunk {chunk} logits @ {threads} threads");
            // and every K/V row written along the way is identical too
            // (read position-by-position through the paged block tables)
            for li in 0..sess.cfg.n_layers {
                for pos in 0..plen {
                    assert_eq!(dense_cache.k_row(li, pos),
                               ref_dense.k_row(li, pos),
                               "dense K layer {li} pos {pos} chunk {chunk}");
                    assert_eq!(dense_cache.v_row(li, pos),
                               ref_dense.v_row(li, pos),
                               "dense V layer {li} pos {pos} chunk {chunk}");
                    assert_eq!(lr_cache.k_row(li, pos),
                               ref_lr.k_row(li, pos),
                               "lowrank K layer {li} pos {pos} chunk {chunk}");
                    assert_eq!(lr_cache.v_row(li, pos),
                               ref_lr.v_row(li, pos),
                               "lowrank V layer {li} pos {pos} chunk {chunk}");
                }
            }
        }
    }
    exec::set_threads(0);
}

#[test]
fn batched_slots_bitmatch_per_slot_steps() {
    let rt = Runtime::load_default().unwrap();
    let sess = Session::new(&rt, "tiny");
    let mut rng = Rng::new(0xBA7C);
    let params = init_params(&sess.cfg, &mut rng);

    // teacher-forced token streams of unequal length, so the batch
    // composition changes as short streams finish
    let lens = [6usize, 9, 3];
    let streams: Vec<Vec<i32>> = lens
        .iter()
        .map(|&n| {
            (0..n).map(|_| rng.range(1, sess.cfg.vocab) as i32).collect()
        })
        .collect();

    for threads in [1usize, 4] {
        exec::set_threads(threads);
        // per-slot reference: each stream through its own decode_step calls
        let ref_logits: Vec<Vec<zs_svd::tensor::Tensor>> = streams
            .iter()
            .map(|st| {
                let mut c = sess.new_kv_cache();
                st.iter()
                    .map(|&t| sess.decode_step(&params, &mut c, t).unwrap())
                    .collect()
            })
            .collect();

        // batched: step j advances every still-live stream by one token
        // through ONE decode_batch call
        let mut caches: Vec<KvCache> =
            (0..streams.len()).map(|_| sess.new_kv_cache()).collect();
        let max_len = *lens.iter().max().unwrap();
        for j in 0..max_len {
            let mut live: Vec<usize> = Vec::new();
            let mut seqs: Vec<(&mut KvCache, &[i32])> = Vec::new();
            for (s, c) in caches.iter_mut().enumerate() {
                if j < streams[s].len() {
                    live.push(s);
                    seqs.push((c, &streams[s][j..j + 1]));
                }
            }
            let want = vec![true; seqs.len()];
            let logits = sess.decode_batch(&params, &mut seqs, &want).unwrap();
            assert_eq!(logits.len(), live.len());
            for (b, &s) in live.iter().enumerate() {
                assert_eq!(logits[b].as_ref().unwrap().data,
                           ref_logits[s][j].data,
                           "stream {s} step {j} @ {threads} threads: \
                            batched-across-slots must bit-match per-slot");
            }
        }
        for (s, c) in caches.iter().enumerate() {
            assert_eq!(c.len, streams[s].len());
        }
    }
    exec::set_threads(0);
}

#[test]
fn continuous_batching_serves_every_request_exactly_once() {
    let rt = Runtime::load_default().unwrap();
    let sess = Session::new(&rt, "tiny");
    let mut rng = Rng::new(0xBA7);
    let params = init_params(&sess.cfg, &mut rng);

    // saturating arrivals: 9 requests into 3 slots, all eligible at t=0;
    // 12-token prompts over a 5-token prefill chunk exercise the ragged
    // chunked-prefill path (5 + 5 + 2) under continuous batching
    let cfg = DecodeConfig { max_slots: 3, max_new_tokens: 4, temperature: 0.0,
                             seed: 5, arrival_steps: 0.0, prefill_chunk: 5,
                             speculate_k: 0, ..DecodeConfig::default() };
    let reqs = synth_requests(&sess.cfg, 9, 12, 4, 0xFEED);
    let (stats, done) = run_decode(&sess, &params, &Engine::Dense, &reqs, &cfg)
        .unwrap();

    assert_eq!(stats.requests, 9);
    assert_eq!(done.len(), 9);
    let ids: Vec<usize> = done.iter().map(|c| c.id).collect();
    assert_eq!(ids, (0..9).collect::<Vec<_>>(), "each id exactly once");
    for c in &done {
        assert_eq!(c.tokens.len(), 4, "request {} budget", c.id);
        assert!(c.tokens.iter().all(|&t| t >= 0
                    && (t as usize) < sess.cfg.vocab));
        assert!(c.latency_ms >= c.ttft_ms);
    }
    assert_eq!(stats.decode_tokens, 9 * 4);
    assert_eq!(stats.prefill_tokens, 9 * 12);
    assert!(stats.decode_tok_per_sec > 0.0);
    assert!(stats.latency.p95 >= stats.latency.p50);
    assert!(stats.latency.p99 >= stats.latency.p95);
    assert!(stats.kv_bytes_per_slot > 0);
}

#[test]
fn generation_is_reproducible_and_slot_count_invariant() {
    let rt = Runtime::load_default().unwrap();
    let sess = Session::new(&rt, "tiny");
    let mut rng = Rng::new(0x9E4);
    let params = init_params(&sess.cfg, &mut rng);
    let reqs = synth_requests(&sess.cfg, 5, 8, 6, 0xAB);

    let run = |slots: usize, temperature: f32, prefill_chunk: usize| {
        let cfg = DecodeConfig { max_slots: slots, max_new_tokens: 6,
                                 temperature, seed: 11, arrival_steps: 0.0,
                                 prefill_chunk, speculate_k: 0,
                                 ..DecodeConfig::default() };
        let (_, done) = run_decode(&sess, &params, &Engine::Dense, &reqs, &cfg)
            .unwrap();
        done.into_iter().map(|c| c.tokens).collect::<Vec<_>>()
    };

    // greedy and temperature sampling are both deterministic per request,
    // so tokens cannot depend on the slot count (scheduling) at all
    assert_eq!(run(1, 0.0, 0), run(4, 0.0, 0));
    assert_eq!(run(2, 0.8, 0), run(3, 0.8, 0));
    // and repeated runs reproduce exactly
    assert_eq!(run(2, 0.8, 0), run(2, 0.8, 0));
    // the prefill chunk size chooses WHEN prompt tokens are ingested,
    // never what the model computes: any chunking reproduces the
    // whole-prompt tokens exactly
    assert_eq!(run(4, 0.0, 0), run(4, 0.0, 1));
    assert_eq!(run(4, 0.0, 0), run(4, 0.0, 3));
    assert_eq!(run(2, 0.8, 0), run(2, 0.8, 3));
}

#[test]
fn generation_respects_kv_capacity() {
    let rt = Runtime::load_default().unwrap();
    let sess = Session::new(&rt, "tiny");
    let mut rng = Rng::new(0xCAFE);
    let params = init_params(&sess.cfg, &mut rng);
    let seq = sess.cfg.seq_len;

    // prompt nearly fills the arena: the budget of 10 must be cut short
    let reqs = vec![DecodeRequest::new(0, vec![1i32; seq - 2], 10)];
    let cfg = DecodeConfig { max_slots: 1, max_new_tokens: 10,
                             temperature: 0.0, seed: 1, arrival_steps: 0.0,
                             prefill_chunk: 0, speculate_k: 0,
                             ..DecodeConfig::default() };
    let (stats, done) = run_decode(&sess, &params, &Engine::Dense, &reqs, &cfg)
        .unwrap();
    // prefill leaves 2 free positions; each decode step consumes one, and
    // the token sampled from the arena-filling step still counts
    assert_eq!(done[0].tokens.len(), 3);
    assert_eq!(stats.decode_tokens, 3);
    // the cut-short budget is no longer silent
    assert!(done[0].truncated, "capacity cut must be flagged");
}

#[test]
fn zero_token_budget_is_rejected() {
    // the old scheduler silently coerced max_new_tokens == 0 to 1; it is
    // now a validation error before any slot is touched
    let rt = Runtime::load_default().unwrap();
    let sess = Session::new(&rt, "tiny");
    let mut rng = Rng::new(0x0B0);
    let params = init_params(&sess.cfg, &mut rng);
    let reqs = vec![DecodeRequest::new(0, vec![1, 2, 3], 0)];
    let cfg = DecodeConfig::default();
    let err = run_decode(&sess, &params, &Engine::Dense, &reqs, &cfg)
        .unwrap_err();
    assert!(err.to_string().contains("max_new_tokens"), "{err}");
}

#[test]
fn verify_mode_logits_bitmatch_stepwise_reference() {
    // the speculative contract at the kernel level: an All-mode batched run
    // returns, at every run position j, the bit-exact logits row the
    // token-at-a-time step path produces at that position — dense and
    // low-rank engines both
    let rt = Runtime::load_default().unwrap();
    let sess = Session::new(&rt, "tiny");
    let mut rng = Rng::new(0xA11);
    let params = init_params(&sess.cfg, &mut rng);
    let tag = "60";
    let factors = synthetic_factors(&sess, tag, &mut rng);
    let v = sess.cfg.vocab;

    let toks: Vec<i32> = (0..9)
        .map(|_| rng.range(1, sess.cfg.vocab) as i32)
        .collect();
    let split = 4usize;

    // token-at-a-time reference rows over the whole stream
    let mut ref_dense = sess.new_kv_cache();
    let mut ref_lr = sess.new_kv_cache();
    let dense_ref: Vec<Vec<f32>> = toks.iter()
        .map(|&t| sess.decode_step(&params, &mut ref_dense, t).unwrap().data)
        .collect();
    let lr_ref: Vec<Vec<f32>> = toks.iter()
        .map(|&t| sess
            .lowrank_decode_step(tag, &params, &factors, &mut ref_lr, t)
            .unwrap()
            .data)
        .collect();

    // dense: ingest a prefix without logits, then score the rest All-mode
    let mut cache = sess.new_kv_cache();
    {
        let mut seqs = vec![(&mut cache, &toks[..split])];
        sess.decode_batch(&params, &mut seqs, &[false]).unwrap();
    }
    let all = {
        let mut seqs = vec![(&mut cache, &toks[split..])];
        sess.decode_batch_modes(&params, &mut seqs, &[LogitsMode::All])
            .unwrap()
            .remove(0)
            .expect("All mode returns a matrix")
    };
    assert_eq!(all.rows, toks.len() - split);
    assert_eq!(all.cols, v);
    for j in 0..all.rows {
        assert_eq!(all.row(j), &dense_ref[split + j][..],
                   "dense All-mode row {j}");
    }

    // low-rank: same contract, plus Last/None on a fresh run
    let mut lr_cache = sess.new_kv_cache();
    {
        let mut seqs = vec![(&mut lr_cache, &toks[..split])];
        sess.lowrank_decode_batch(tag, &params, &factors, &mut seqs, &[false])
            .unwrap();
    }
    let all = {
        let mut seqs = vec![(&mut lr_cache, &toks[split..])];
        sess.lowrank_decode_batch_modes(tag, &params, &factors, &mut seqs,
                                        &[LogitsMode::All])
            .unwrap()
            .remove(0)
            .expect("All mode returns a matrix")
    };
    for j in 0..all.rows {
        assert_eq!(all.row(j), &lr_ref[split + j][..],
                   "lowrank All-mode row {j}");
    }

    // Last returns exactly the final row; None returns nothing (and both
    // advance the cursor just the same)
    let mut c_last = sess.new_kv_cache();
    let mut c_none = sess.new_kv_cache();
    let last = {
        let mut seqs = vec![(&mut c_last, &toks[..])];
        sess.decode_batch_modes(&params, &mut seqs, &[LogitsMode::Last])
            .unwrap()
            .remove(0)
            .expect("Last mode returns one row")
    };
    assert_eq!(last.rows, 1);
    assert_eq!(last.row(0), &dense_ref[toks.len() - 1][..]);
    let none = {
        let mut seqs = vec![(&mut c_none, &toks[..])];
        sess.decode_batch_modes(&params, &mut seqs, &[LogitsMode::None])
            .unwrap()
            .remove(0)
    };
    assert!(none.is_none());
    assert_eq!(c_last.len, toks.len());
    assert_eq!(c_none.len, toks.len());
}

#[test]
fn speculative_decode_bitmatches_plain_greedy() {
    // the tentpole invariant: a dense target verifying a low-rank drafter's
    // proposals generates EXACTLY the tokens plain dense decode does, for
    // every draft depth K and thread count — speculation may only change
    // how many tokens commit per iteration.  One test fn for the whole
    // sweep: exec::set_threads is process-global.
    let rt = Runtime::load_default().unwrap();
    let sess = Session::new(&rt, "tiny");
    let mut rng = Rng::new(0x5BEC);
    let params = init_params(&sess.cfg, &mut rng);
    let drafter = Engine::Lowrank {
        tag: "60".into(),
        factors: synthetic_factors(&sess, "60", &mut rng),
    };

    // 7 requests into 3 slots, ragged chunked prefill, one slot running at
    // temperature (speculation must skip it and still bit-match)
    let mut reqs = synth_requests(&sess.cfg, 7, 10, 6, 0xF00D);
    reqs[2].temperature = Some(0.8);
    reqs[2].seed = Some(99);
    let cfg_for = |k: usize| DecodeConfig {
        max_slots: 3, max_new_tokens: 6, temperature: 0.0, seed: 11,
        arrival_steps: 0.0, prefill_chunk: 4, speculate_k: k,
        ..DecodeConfig::default()
    };

    for threads in [1usize, 4] {
        exec::set_threads(threads);
        let (_, plain) = run_decode(&sess, &params, &Engine::Dense, &reqs,
                                    &cfg_for(0)).unwrap();
        let plain_tokens: Vec<Vec<i32>> =
            plain.iter().map(|c| c.tokens.clone()).collect();
        for k in [1usize, 2, 4] {
            let (stats, done) = run_decode_speculative(
                &sess, &params, &Engine::Dense, &drafter, &reqs,
                &cfg_for(k)).unwrap();
            let got: Vec<Vec<i32>> =
                done.iter().map(|c| c.tokens.clone()).collect();
            assert_eq!(got, plain_tokens,
                       "speculative K={k} @ {threads} threads must \
                        bit-match plain greedy decode");
            assert_eq!(stats.engine, format!("dense+spec-k{k}"));
            assert!(stats.drafted_tokens > 0,
                    "K={k}: the greedy slots must actually draft");
            assert!(stats.accepted_draft_tokens <= stats.drafted_tokens);
            assert!((0.0..=1.0).contains(&stats.draft_acceptance));
        }
    }
    exec::set_threads(0);
}

#[test]
fn speculative_decode_respects_kv_capacity() {
    // the drafter/verify rollback arithmetic at the arena boundary: a
    // prompt leaving only 2 free positions must yield exactly the plain
    // path's 3 tokens (flagged truncated) for any K, and a prompt that
    // FILLS the arena yields exactly one token without ever running a
    // verify round
    let rt = Runtime::load_default().unwrap();
    let sess = Session::new(&rt, "tiny");
    let mut rng = Rng::new(0xED6E);
    let params = init_params(&sess.cfg, &mut rng);
    let seq = sess.cfg.seq_len;
    let drafter = Engine::Lowrank {
        tag: "60".into(),
        factors: synthetic_factors(&sess, "60", &mut rng),
    };
    let cfg_for = |k: usize| DecodeConfig {
        max_slots: 1, max_new_tokens: 10, temperature: 0.0, seed: 1,
        arrival_steps: 0.0, prefill_chunk: 0, speculate_k: k,
        ..DecodeConfig::default()
    };

    let near = vec![DecodeRequest::new(0, vec![1i32; seq - 2], 10)];
    let (_, plain) = run_decode(&sess, &params, &Engine::Dense, &near,
                                &cfg_for(0)).unwrap();
    assert_eq!(plain[0].tokens.len(), 3);
    assert!(plain[0].truncated);
    for k in [1usize, 4] {
        let (_, done) = run_decode_speculative(
            &sess, &params, &Engine::Dense, &drafter, &near, &cfg_for(k))
            .unwrap();
        assert_eq!(done[0].tokens, plain[0].tokens, "K={k} at the boundary");
        assert!(done[0].truncated, "K={k}: the cut must still be flagged");
    }

    // prompt == seq_len: the arena is full the moment prefill ends — one
    // token comes from the prompt logits, then the slot retires truncated
    let full = vec![DecodeRequest::new(0, vec![1i32; seq], 10)];
    for k in [0usize, 2] {
        let run = |k: usize| {
            if k == 0 {
                run_decode(&sess, &params, &Engine::Dense, &full, &cfg_for(0))
            } else {
                run_decode_speculative(&sess, &params, &Engine::Dense,
                                       &drafter, &full, &cfg_for(k))
            }
        };
        let (_, done) = run(k).unwrap();
        assert_eq!(done[0].tokens.len(), 1, "K={k}");
        assert!(done[0].truncated, "K={k}");
    }

    // same full-arena prompt with a budget of exactly 1: the request got
    // everything it asked for, so it is NOT truncated
    let one = vec![DecodeRequest::new(0, vec![1i32; seq], 1)];
    let cfg1 = DecodeConfig { max_new_tokens: 1, ..cfg_for(2) };
    let (_, done) = run_decode_speculative(&sess, &params, &Engine::Dense,
                                           &drafter, &one, &cfg1).unwrap();
    assert_eq!(done[0].tokens.len(), 1);
    assert!(!done[0].truncated, "budget-done beats capacity-done");
}
