//! Decode parity gate + continuous-batching contract.
//!
//! The parity half proves the headline invariants of the decode subsystem:
//!
//! * for a fixed prefix, the KV-cached incremental path reproduces the full
//!   forward's last-token logits BIT-EXACTLY, for threads {1, 2, 4}, on
//!   both the dense and the low-rank engines;
//! * the batched `decode_batch` kernel reproduces the token-at-a-time
//!   `decode_step` reference BIT-EXACTLY for every prefill chunking
//!   (chunk sizes {1, 3, full} leave identical KV contents and logits) and
//!   for every across-slot batch composition, at threads {1, 4}.
//!
//! Everything thread-global lives in ONE test function per sweep
//! (`exec::set_threads` is process-wide, same pattern as
//! `parallel_equiv.rs`); the scheduler tests rely only on results that are
//! thread-count independent by construction.
//!
//! Kernel backends: ci.sh re-runs this whole gate under `PALLAS_NO_SIMD=1`,
//! so every parity invariant is proven on BOTH the SIMD and the portable
//! backend (the backends themselves are bit-identical — see
//! `rust/tests/kernel_equiv.rs`, which also cross-checks decode logits
//! across backends directly).  `force_backend` is deliberately not flipped
//! here: it is process-global, and the tests in this binary run
//! concurrently.

use std::collections::BTreeMap;

use zs_svd::decode::{run_decode, synth_requests, DecodeConfig, DecodeRequest,
                     KvCache};
use zs_svd::exec;
use zs_svd::model::init::init_params;
use zs_svd::runtime::session::Session;
use zs_svd::runtime::Runtime;
use zs_svd::serve::Engine;
use zs_svd::tensor::Mat;
use zs_svd::util::rng::Rng;

/// Uniform-rank random factors matching the artifact ranks of `tag` — valid
/// for both `lowrank_fwd` and `lowrank_decode_step`.
fn synthetic_factors(sess: &Session, tag: &str, rng: &mut Rng)
                     -> BTreeMap<String, (Mat, Mat)> {
    let lm = sess.cfg.lowrank.get(tag).expect("artifact tag");
    sess.cfg
        .targets
        .iter()
        .map(|t| {
            let (m, n) = t.shape;
            let k = lm.ranks[&t.name];
            (t.name.clone(),
             (Mat::randn(rng, m, k, 0.05), Mat::randn(rng, k, n, 0.05)))
        })
        .collect()
}

#[test]
fn decode_bitmatches_forward_for_all_thread_counts() {
    let rt = Runtime::load_default().unwrap();
    let sess = Session::new(&rt, "tiny");
    let mut rng = Rng::new(0xDECD);
    let params = init_params(&sess.cfg, &mut rng);
    let seq = sess.cfg.seq_len;
    let tag = "60";
    let factors = synthetic_factors(&sess, tag, &mut rng);

    // one fixed (1, T+1) token row; the full forward sees all of it, the
    // decode path replays prefixes of it
    let tokens: Vec<i32> = (0..seq + 1)
        .map(|_| rng.range(1, sess.cfg.vocab) as i32)
        .collect();
    let full = zs_svd::tensor::IntTensor::from_vec(&[1, seq + 1], tokens.clone());

    for t in [1usize, 2, 4] {
        exec::set_threads(t);
        let (_, dense_logits) = sess.fwd(&params, &full).unwrap();
        let (_, lr_logits) = sess.lowrank_fwd(tag, &params, &factors, &full)
            .unwrap();

        let mut dense_cache = sess.new_kv_cache();
        let mut lr_cache = sess.new_kv_cache();
        for pos in 0..seq {
            let d_step = sess.decode_step(&params, &mut dense_cache, tokens[pos])
                .unwrap();
            let l_step = sess
                .lowrank_decode_step(tag, &params, &factors, &mut lr_cache,
                                     tokens[pos])
                .unwrap();
            // causality: forward row `pos` only sees tokens 0..=pos, so the
            // step logits must reproduce it bit for bit
            let v = sess.cfg.vocab;
            assert_eq!(&d_step.data[..], &dense_logits.data[pos * v..(pos + 1) * v],
                       "dense decode != forward at pos {pos}, {t} threads");
            assert_eq!(&l_step.data[..], &lr_logits.data[pos * v..(pos + 1) * v],
                       "lowrank decode != forward at pos {pos}, {t} threads");
        }
        assert_eq!(dense_cache.len, seq);
        assert_eq!(lr_cache.len, seq);
    }
    exec::set_threads(0);
}

#[test]
fn decode_matches_forward_on_opt_arch() {
    // learned positions + LayerNorm + GELU take a different step path than
    // llama; the parity invariant must hold there too (thread-count
    // independence is already guaranteed by the kernels, so no sweep)
    let rt = Runtime::load_default().unwrap();
    let sess = Session::new(&rt, "opt_tiny");
    let mut rng = Rng::new(0x0F7);
    let params = init_params(&sess.cfg, &mut rng);
    let seq = sess.cfg.seq_len;
    let tokens: Vec<i32> = (0..seq + 1)
        .map(|_| rng.range(1, sess.cfg.vocab) as i32)
        .collect();
    let full = zs_svd::tensor::IntTensor::from_vec(&[1, seq + 1], tokens.clone());
    let (_, logits) = sess.fwd(&params, &full).unwrap();
    let mut cache = sess.new_kv_cache();
    let v = sess.cfg.vocab;
    for pos in 0..seq {
        let step = sess.decode_step(&params, &mut cache, tokens[pos]).unwrap();
        assert_eq!(&step.data[..], &logits.data[pos * v..(pos + 1) * v],
                   "opt decode != forward at pos {pos}");
    }
}

#[test]
fn chunked_prefill_bitmatches_token_at_a_time() {
    let rt = Runtime::load_default().unwrap();
    let sess = Session::new(&rt, "tiny");
    let mut rng = Rng::new(0xC4A);
    let params = init_params(&sess.cfg, &mut rng);
    let tag = "60";
    let factors = synthetic_factors(&sess, tag, &mut rng);
    let d = sess.cfg.d_model;

    // prompt length indivisible by 3 so the last chunk is ragged
    let plen = 11usize;
    let prompt: Vec<i32> = (0..plen)
        .map(|_| rng.range(1, sess.cfg.vocab) as i32)
        .collect();

    for threads in [1usize, 4] {
        exec::set_threads(threads);
        // token-at-a-time reference through the incremental step kernel
        let mut ref_dense = sess.new_kv_cache();
        let mut ref_lr = sess.new_kv_cache();
        let mut ref_dense_logits = None;
        let mut ref_lr_logits = None;
        for &t in &prompt {
            ref_dense_logits =
                Some(sess.decode_step(&params, &mut ref_dense, t).unwrap());
            ref_lr_logits = Some(
                sess.lowrank_decode_step(tag, &params, &factors, &mut ref_lr, t)
                    .unwrap(),
            );
        }

        for chunk in [1usize, 3, plen] {
            let mut dense_cache = sess.new_kv_cache();
            let mut lr_cache = sess.new_kv_cache();
            let mut dense_logits = None;
            let mut lr_logits = None;
            let mut pos = 0usize;
            while pos < plen {
                let end = (pos + chunk).min(plen);
                // logits are requested only for the prompt-completing
                // chunk, exactly as the scheduler drives prefill
                let last = end == plen;
                {
                    let mut seqs =
                        vec![(&mut dense_cache, &prompt[pos..end])];
                    let got = sess.decode_batch(&params, &mut seqs, &[last])
                        .unwrap()
                        .remove(0);
                    assert_eq!(got.is_some(), last,
                               "logits exactly when requested");
                    if last {
                        dense_logits = got;
                    }
                }
                {
                    let mut seqs = vec![(&mut lr_cache, &prompt[pos..end])];
                    let got = sess
                        .lowrank_decode_batch(tag, &params, &factors,
                                              &mut seqs, &[last])
                        .unwrap()
                        .remove(0);
                    assert_eq!(got.is_some(), last,
                               "logits exactly when requested");
                    if last {
                        lr_logits = got;
                    }
                }
                pos = end;
            }
            assert_eq!(dense_cache.len, plen);
            assert_eq!(lr_cache.len, plen);
            // the final chunk's logits are the last prompt position's
            assert_eq!(dense_logits.unwrap().data,
                       ref_dense_logits.as_ref().unwrap().data,
                       "dense chunk {chunk} logits @ {threads} threads");
            assert_eq!(lr_logits.unwrap().data,
                       ref_lr_logits.as_ref().unwrap().data,
                       "lowrank chunk {chunk} logits @ {threads} threads");
            // and every K/V row written along the way is identical too
            for li in 0..sess.cfg.n_layers {
                assert_eq!(&dense_cache.k[li].data[..plen * d],
                           &ref_dense.k[li].data[..plen * d],
                           "dense K layer {li} chunk {chunk}");
                assert_eq!(&dense_cache.v[li].data[..plen * d],
                           &ref_dense.v[li].data[..plen * d],
                           "dense V layer {li} chunk {chunk}");
                assert_eq!(&lr_cache.k[li].data[..plen * d],
                           &ref_lr.k[li].data[..plen * d],
                           "lowrank K layer {li} chunk {chunk}");
                assert_eq!(&lr_cache.v[li].data[..plen * d],
                           &ref_lr.v[li].data[..plen * d],
                           "lowrank V layer {li} chunk {chunk}");
            }
        }
    }
    exec::set_threads(0);
}

#[test]
fn batched_slots_bitmatch_per_slot_steps() {
    let rt = Runtime::load_default().unwrap();
    let sess = Session::new(&rt, "tiny");
    let mut rng = Rng::new(0xBA7C);
    let params = init_params(&sess.cfg, &mut rng);

    // teacher-forced token streams of unequal length, so the batch
    // composition changes as short streams finish
    let lens = [6usize, 9, 3];
    let streams: Vec<Vec<i32>> = lens
        .iter()
        .map(|&n| {
            (0..n).map(|_| rng.range(1, sess.cfg.vocab) as i32).collect()
        })
        .collect();

    for threads in [1usize, 4] {
        exec::set_threads(threads);
        // per-slot reference: each stream through its own decode_step calls
        let ref_logits: Vec<Vec<zs_svd::tensor::Tensor>> = streams
            .iter()
            .map(|st| {
                let mut c = sess.new_kv_cache();
                st.iter()
                    .map(|&t| sess.decode_step(&params, &mut c, t).unwrap())
                    .collect()
            })
            .collect();

        // batched: step j advances every still-live stream by one token
        // through ONE decode_batch call
        let mut caches: Vec<KvCache> =
            (0..streams.len()).map(|_| sess.new_kv_cache()).collect();
        let max_len = *lens.iter().max().unwrap();
        for j in 0..max_len {
            let mut live: Vec<usize> = Vec::new();
            let mut seqs: Vec<(&mut KvCache, &[i32])> = Vec::new();
            for (s, c) in caches.iter_mut().enumerate() {
                if j < streams[s].len() {
                    live.push(s);
                    seqs.push((c, &streams[s][j..j + 1]));
                }
            }
            let want = vec![true; seqs.len()];
            let logits = sess.decode_batch(&params, &mut seqs, &want).unwrap();
            assert_eq!(logits.len(), live.len());
            for (b, &s) in live.iter().enumerate() {
                assert_eq!(logits[b].as_ref().unwrap().data,
                           ref_logits[s][j].data,
                           "stream {s} step {j} @ {threads} threads: \
                            batched-across-slots must bit-match per-slot");
            }
        }
        for (s, c) in caches.iter().enumerate() {
            assert_eq!(c.len, streams[s].len());
        }
    }
    exec::set_threads(0);
}

#[test]
fn continuous_batching_serves_every_request_exactly_once() {
    let rt = Runtime::load_default().unwrap();
    let sess = Session::new(&rt, "tiny");
    let mut rng = Rng::new(0xBA7);
    let params = init_params(&sess.cfg, &mut rng);

    // saturating arrivals: 9 requests into 3 slots, all eligible at t=0;
    // 12-token prompts over a 5-token prefill chunk exercise the ragged
    // chunked-prefill path (5 + 5 + 2) under continuous batching
    let cfg = DecodeConfig { max_slots: 3, max_new_tokens: 4, temperature: 0.0,
                             seed: 5, arrival_steps: 0.0, prefill_chunk: 5 };
    let reqs = synth_requests(&sess.cfg, 9, 12, 4, 0xFEED);
    let (stats, done) = run_decode(&sess, &params, &Engine::Dense, &reqs, &cfg)
        .unwrap();

    assert_eq!(stats.requests, 9);
    assert_eq!(done.len(), 9);
    let ids: Vec<usize> = done.iter().map(|c| c.id).collect();
    assert_eq!(ids, (0..9).collect::<Vec<_>>(), "each id exactly once");
    for c in &done {
        assert_eq!(c.tokens.len(), 4, "request {} budget", c.id);
        assert!(c.tokens.iter().all(|&t| t >= 0
                    && (t as usize) < sess.cfg.vocab));
        assert!(c.latency_ms >= c.ttft_ms);
    }
    assert_eq!(stats.decode_tokens, 9 * 4);
    assert_eq!(stats.prefill_tokens, 9 * 12);
    assert!(stats.decode_tok_per_sec > 0.0);
    assert!(stats.latency.p95 >= stats.latency.p50);
    assert!(stats.latency.p99 >= stats.latency.p95);
    assert!(stats.kv_bytes_per_slot > 0);
}

#[test]
fn generation_is_reproducible_and_slot_count_invariant() {
    let rt = Runtime::load_default().unwrap();
    let sess = Session::new(&rt, "tiny");
    let mut rng = Rng::new(0x9E4);
    let params = init_params(&sess.cfg, &mut rng);
    let reqs = synth_requests(&sess.cfg, 5, 8, 6, 0xAB);

    let run = |slots: usize, temperature: f32, prefill_chunk: usize| {
        let cfg = DecodeConfig { max_slots: slots, max_new_tokens: 6,
                                 temperature, seed: 11, arrival_steps: 0.0,
                                 prefill_chunk };
        let (_, done) = run_decode(&sess, &params, &Engine::Dense, &reqs, &cfg)
            .unwrap();
        done.into_iter().map(|c| c.tokens).collect::<Vec<_>>()
    };

    // greedy and temperature sampling are both deterministic per request,
    // so tokens cannot depend on the slot count (scheduling) at all
    assert_eq!(run(1, 0.0, 0), run(4, 0.0, 0));
    assert_eq!(run(2, 0.8, 0), run(3, 0.8, 0));
    // and repeated runs reproduce exactly
    assert_eq!(run(2, 0.8, 0), run(2, 0.8, 0));
    // the prefill chunk size chooses WHEN prompt tokens are ingested,
    // never what the model computes: any chunking reproduces the
    // whole-prompt tokens exactly
    assert_eq!(run(4, 0.0, 0), run(4, 0.0, 1));
    assert_eq!(run(4, 0.0, 0), run(4, 0.0, 3));
    assert_eq!(run(2, 0.8, 0), run(2, 0.8, 3));
}

#[test]
fn generation_respects_kv_capacity() {
    let rt = Runtime::load_default().unwrap();
    let sess = Session::new(&rt, "tiny");
    let mut rng = Rng::new(0xCAFE);
    let params = init_params(&sess.cfg, &mut rng);
    let seq = sess.cfg.seq_len;

    // prompt nearly fills the arena: the budget of 10 must be cut short
    let reqs = vec![DecodeRequest::new(0, vec![1i32; seq - 2], 10)];
    let cfg = DecodeConfig { max_slots: 1, max_new_tokens: 10,
                             temperature: 0.0, seed: 1, arrival_steps: 0.0,
                             prefill_chunk: 0 };
    let (stats, done) = run_decode(&sess, &params, &Engine::Dense, &reqs, &cfg)
        .unwrap();
    // prefill leaves 2 free positions; each decode step consumes one, and
    // the token sampled from the arena-filling step still counts
    assert_eq!(done[0].tokens.len(), 3);
    assert_eq!(stats.decode_tokens, 3);
}
