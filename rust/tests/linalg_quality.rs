//! Linalg kernel quality gates at the shapes the shipped configs hit.
//!
//! These back the blocked/parallel kernel rewrite: reconstruction and
//! orthogonality tolerances are set tight enough that a wrong block edge,
//! a dropped accumulation, or a transposed index shows up immediately,
//! while leaving ~10× headroom over the kernels' observed f32 error so the
//! tests are not flaky across platforms.

use zs_svd::linalg::qr::qr;
use zs_svd::linalg::{cholesky, gram, matmul, matmul_bt, reconstruct,
                     solve_lower, solve_lower_t, svd, tail_energy};
use zs_svd::tensor::Mat;
use zs_svd::util::rng::Rng;

fn max_rel_dev(a: &Mat, b: &Mat) -> f64 {
    assert_eq!((a.rows, a.cols), (b.rows, b.cols));
    a.data
        .iter()
        .zip(&b.data)
        .map(|(&x, &y)| {
            ((x - y).abs() / (1.0 + x.abs().max(y.abs()))) as f64
        })
        .fold(0.0, f64::max)
}

fn spd(rng: &mut Rng, n: usize) -> Mat {
    let x = Mat::randn(rng, 2 * n, n, 1.0);
    let mut c = gram(&x);
    c.add_diag(0.05 * n as f32);
    c
}

#[test]
fn svd_reconstruction_and_value_ordering_at_config_shapes() {
    let mut rng = Rng::new(101);
    for (m, n) in [(128usize, 128usize), (352, 128), (128, 352), (192, 512)] {
        let a = Mat::randn(&mut rng, m, n, 1.0);
        let s = svd(&a);
        let r = m.min(n);
        assert_eq!(s.sigma.len(), r);
        // descending, non-negative
        for w in s.sigma.windows(2) {
            assert!(w[0] >= w[1] - 1e-5, "{m}x{n}: sigma not sorted {w:?}");
        }
        assert!(s.sigma[r - 1] >= -1e-6);
        // full-rank reconstruction: relative Frobenius error
        let rec = reconstruct(&s, r);
        let err = a.sub(&rec).frob_norm() / a.frob_norm().max(1e-12);
        assert!(err < 1e-4, "{m}x{n}: svd reconstruction error {err}");
        // Eckart–Young: rank-k error² == tail energy, k = r/2
        let k = r / 2;
        let err2 = a.sub(&reconstruct(&s, k)).frob_norm().powi(2);
        let tail = tail_energy(&s.sigma, k);
        assert!((err2 - tail).abs() / tail.max(1e-9) < 1e-2,
                "{m}x{n}: err² {err2} vs tail {tail}");
    }
}

#[test]
fn svd_singular_vectors_orthonormal() {
    let mut rng = Rng::new(102);
    let a = Mat::randn(&mut rng, 352, 128, 1.0);
    let s = svd(&a);
    for (mat, label) in [(&s.u, "U"), (&s.v, "V")] {
        let g = matmul(&mat.transpose(), mat);
        let dev = max_rel_dev(&g, &Mat::eye(g.rows));
        assert!(dev < 1e-4, "{label}ᵀ{label} deviates from I by {dev}");
    }
}

#[test]
fn qr_orthogonality_and_reconstruction() {
    let mut rng = Rng::new(103);
    for (m, n) in [(128usize, 128usize), (352, 128), (200, 64)] {
        let a = Mat::randn(&mut rng, m, n, 1.0);
        let (q, r) = qr(&a);
        let dev = max_rel_dev(&matmul(&q.transpose(), &q), &Mat::eye(n));
        assert!(dev < 1e-4, "{m}x{n}: QᵀQ deviates by {dev}");
        let rec_err = matmul(&q, &r).sub(&a).frob_norm() / a.frob_norm();
        assert!(rec_err < 1e-4, "{m}x{n}: QR reconstruction error {rec_err}");
        // R upper-triangular exactly
        for i in 0..n {
            for j in 0..i {
                assert_eq!(r.at(i, j), 0.0);
            }
        }
    }
}

#[test]
fn cholesky_roundtrip_and_solves_on_random_spd() {
    let mut rng = Rng::new(104);
    for n in [128usize, 352, 512] {
        let c = spd(&mut rng, n);
        let l = cholesky(&c).expect("SPD input must factor");
        // LLᵀ == C
        let rec = matmul_bt(&l, &l);
        let dev = max_rel_dev(&rec, &c);
        assert!(dev < 1e-4, "n={n}: LLᵀ deviates by {dev}");
        // strict upper part exactly zero
        for i in 0..n {
            for j in i + 1..n {
                assert_eq!(l.at(i, j), 0.0);
            }
        }
        // forward/backward triangular solves
        let b = Mat::randn(&mut rng, n, 8, 1.0);
        let x = solve_lower(&l, &b);
        let res = matmul(&l, &x).sub(&b).frob_norm() / b.frob_norm();
        assert!(res < 1e-4, "n={n}: forward solve residual {res}");
        let y = solve_lower_t(&l, &b);
        let res = matmul(&l.transpose(), &y).sub(&b).frob_norm() / b.frob_norm();
        assert!(res < 1e-4, "n={n}: backward solve residual {res}");
    }
}

#[test]
fn blocked_matmul_matches_f64_reference_at_config_shapes() {
    let mut rng = Rng::new(105);
    for (m, k, n) in [(352usize, 128usize, 352usize), (128, 352, 128),
                      (512, 192, 512), (131, 257, 67)] {
        let a = Mat::randn(&mut rng, m, k, 1.0);
        let b = Mat::randn(&mut rng, k, n, 1.0);
        let c = matmul(&a, &b);
        let mut reference = Mat::zeros(m, n);
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0f64;
                for kk in 0..k {
                    s += a.at(i, kk) as f64 * b.at(kk, j) as f64;
                }
                *reference.at_mut(i, j) = s as f32;
            }
        }
        let dev = max_rel_dev(&c, &reference);
        assert!(dev < 1e-4, "{m}x{k}x{n}: matmul deviates by {dev}");
        // Bᵀ variant against the materialized transpose
        let cbt = matmul_bt(&a, &b.transpose());
        let dev = max_rel_dev(&cbt, &reference);
        assert!(dev < 1e-4, "{m}x{k}x{n}: matmul_bt deviates by {dev}");
    }
}
