//! Bit-identity gate for the paged KV pool + prefix-sharing cache.
//!
//! Prefix caching is a pure serving optimization: a prompt that hits the
//! prefix tree adopts shared read-only blocks and skips prefill for the
//! matched tokens, but the attention kernels read the exact same f32
//! values they would have recomputed — so generated tokens must be
//! BIT-IDENTICAL with the cache on or off.  This binary proves that:
//!
//! * cold-vs-warm: the same shared-prefix workload served with
//!   `prefix_cache_blocks` = 0 and > 0 generates identical tokens, swept
//!   over threads {1, 4} × prefill chunks {1, 3, full} × speculative
//!   K {0, 2} (the drafter's mirrored cache never shares blocks with the
//!   tree, so speculation must survive a shortened target prefill);
//! * warm requests report exactly the block-aligned shared prefix as
//!   `cached_prompt_tokens`, the cold first request reports 0;
//! * divergence inside a block (a shared prefix that is NOT block-aligned)
//!   matches only up to the last full shared block and still bit-matches;
//! * eviction-then-refill: a tree capped below the working set evicts
//!   LRU-first, a re-sent evicted prompt misses cleanly and regenerates
//!   identical tokens;
//! * the admission-validation regression from the monolithic-arena days: a
//!   malformed request reaching [`run_engine`] fails ALONE with a
//!   `Rejected` emission instead of tearing down the engine loop (the
//!   offline wrapper still hard-errors up front).
//!
//! `exec::set_threads` is process-global, so the thread sweep lives in one
//! test function (same pattern as `trace_equiv.rs`).  ci.sh re-runs this
//! gate under `PALLAS_NO_SIMD=1`, so bit-identity is proven on both the
//! SIMD and the portable kernel backends.

use std::collections::BTreeMap;

use zs_svd::decode::{run_decode, run_decode_speculative, run_engine,
                     synth_requests, synth_requests_shared_prefix,
                     CompletedRequest, DecodeConfig, DecodeEvent,
                     WorkloadSource};
use zs_svd::exec;
use zs_svd::model::init::init_params;
use zs_svd::model::ParamStore;
use zs_svd::runtime::session::Session;
use zs_svd::runtime::Runtime;
use zs_svd::serve::Engine;
use zs_svd::tensor::Mat;
use zs_svd::util::rng::Rng;

/// Uniform-rank random factors matching the artifact ranks of `tag` — the
/// same drafter-engine helper `decode_parity.rs` and `trace_equiv.rs` use.
fn synthetic_factors(sess: &Session, tag: &str, rng: &mut Rng)
                     -> BTreeMap<String, (Mat, Mat)> {
    let lm = sess.cfg.lowrank.get(tag).expect("artifact tag");
    sess.cfg
        .targets
        .iter()
        .map(|t| {
            let (m, n) = t.shape;
            let k = lm.ranks[&t.name];
            (t.name.clone(),
             (Mat::randn(rng, m, k, 0.05), Mat::randn(rng, k, n, 0.05)))
        })
        .collect()
}

fn setup() -> (Session, ParamStore, Rng) {
    let rt = Runtime::load_default().unwrap();
    let sess = Session::new(&rt, "tiny");
    let mut rng = Rng::new(0xB10C);
    let params = init_params(&sess.cfg, &mut rng);
    (sess, params, rng)
}

/// Greedy single-slot config: serial admission makes request 0 the cold
/// fill and every later request a guaranteed warm lookup.
fn cfg_for(chunk: usize, k: usize, blocks: usize) -> DecodeConfig {
    DecodeConfig {
        max_slots: 1,
        max_new_tokens: 4,
        temperature: 0.0,
        seed: 9,
        arrival_steps: 0.0,
        prefill_chunk: chunk,
        speculate_k: k,
        kv_block: 4,
        prefix_cache_blocks: blocks,
    }
}

fn tokens_of(done: &[CompletedRequest]) -> Vec<Vec<i32>> {
    done.iter().map(|c| c.tokens.clone()).collect()
}

#[test]
fn prefix_hits_bit_match_misses_across_threads_chunks_and_speculation() {
    let (sess, params, mut rng) = setup();
    let drafter = Engine::Lowrank {
        tag: "60".into(),
        factors: synthetic_factors(&sess, "60", &mut rng),
    };
    // 5 prompts sharing a 12-token prefix (3 full blocks at kv_block = 4)
    // with 5-token private suffixes: every warm lookup matches exactly the
    // aligned shared prefix (the 4th full block holds suffix tokens and
    // diverges per request)
    let reqs = synth_requests_shared_prefix(&sess.cfg, 5, 12, 5, 4, 0x5EED);

    for threads in [1usize, 4] {
        exec::set_threads(threads);
        for chunk in [1usize, 3, 0] {
            for k in [0usize, 2] {
                let run = |blocks: usize| {
                    let cfg = cfg_for(chunk, k, blocks);
                    let r = if k == 0 {
                        run_decode(&sess, &params, &Engine::Dense, &reqs,
                                   &cfg)
                    } else {
                        run_decode_speculative(&sess, &params,
                                               &Engine::Dense, &drafter,
                                               &reqs, &cfg)
                    };
                    r.expect("decode run").1
                };
                let off = run(0);
                let on = run(64);
                assert_eq!(
                    tokens_of(&off), tokens_of(&on),
                    "prefix cache changed tokens @ threads {threads} \
                     chunk {chunk} K {k}");
                assert!(off.iter().all(|c| c.cached_prompt_tokens == 0),
                        "cache off must never report cached tokens");
                // serial single-slot admission: request 0 fills the tree
                // cold, every later request hits the full aligned prefix
                assert_eq!(on[0].cached_prompt_tokens, 0,
                           "first request cannot hit an empty tree");
                for c in &on[1..] {
                    assert_eq!(
                        c.cached_prompt_tokens, 12,
                        "warm request {} must hit the 12-token aligned \
                         shared prefix @ threads {threads} chunk {chunk} \
                         K {k}", c.id);
                }
            }
        }
    }
    exec::set_threads(0);
}

#[test]
fn divergence_inside_a_block_matches_only_full_shared_blocks() {
    let (sess, params, _) = setup();
    // 14 shared tokens at kv_block = 4: blocks 0..3 are fully shared,
    // block 3 mixes shared positions 12..14 with private suffix tokens —
    // the lookup must stop at the last FULL shared block (12 tokens) and
    // the recomputed tail must keep tokens bit-identical
    let reqs = synth_requests_shared_prefix(&sess.cfg, 4, 14, 5, 4, 0xD1);
    let (_, off) = run_decode(&sess, &params, &Engine::Dense, &reqs,
                              &cfg_for(0, 0, 0)).expect("cache off");
    let (_, on) = run_decode(&sess, &params, &Engine::Dense, &reqs,
                             &cfg_for(0, 0, 64)).expect("cache on");
    assert_eq!(tokens_of(&off), tokens_of(&on),
               "partial-block divergence changed tokens");
    assert_eq!(on[0].cached_prompt_tokens, 0);
    for c in &on[1..] {
        assert_eq!(c.cached_prompt_tokens, 12,
                   "request {}: a mid-block divergence must cap the match \
                    at the last full shared block", c.id);
    }
}

#[test]
fn eviction_then_refill_misses_cleanly_and_stays_deterministic() {
    let (sess, params, _) = setup();
    // 4 fully distinct 17-token prompts, each needing 4 full blocks, into
    // a tree capped at 4 blocks: every insert evicts the previous chain
    let mut reqs = synth_requests(&sess.cfg, 4, 17, 4, 0xE1);
    let mut refill = reqs[0].clone();
    refill.id = 4; // same prompt as request 0, re-sent after its eviction
    reqs.push(refill);

    let cfg = cfg_for(0, 0, 4);
    let mut done: Vec<CompletedRequest> = Vec::new();
    let mut source = WorkloadSource::new(&reqs, 0.0);
    let mut sink = |ev: DecodeEvent| {
        if let DecodeEvent::Done(c) = ev {
            done.push(c);
        }
    };
    let counters = run_engine(&sess, &params, &Engine::Dense, None, &cfg,
                              &mut source, &mut sink)
        .expect("engine run");

    assert_eq!(done.len(), 5);
    assert!(counters.prefix_evictions >= 3,
            "a 4-block cap under 4-block chains must evict per insert \
             (got {})", counters.prefix_evictions);
    // the refill's chain was evicted before it arrived: clean miss...
    let first = done.iter().find(|c| c.id == 0).expect("request 0");
    let again = done.iter().find(|c| c.id == 4).expect("refill request");
    assert_eq!(again.cached_prompt_tokens, 0,
               "an evicted prefix must miss, not resurrect stale blocks");
    // ...and an identical regeneration (greedy, same prompt)
    assert_eq!(first.tokens, again.tokens,
               "eviction-then-refill changed generated tokens");
    assert_eq!(counters.requests_rejected, 0);
}

#[test]
fn malformed_request_fails_alone_without_tearing_down_the_engine() {
    let (sess, params, _) = setup();
    // regression: an oversized prompt reaching the engine loop used to
    // abort the whole run via a hard error, killing every other in-flight
    // generation.  Now each invalid request fails alone.
    let mut reqs = synth_requests(&sess.cfg, 1, 8, 3, 0xBAD);
    reqs[0].id = 3; // the only valid request
    let mut empty = reqs[0].clone();
    empty.id = 0;
    empty.prompt = Vec::new();
    let mut oversized = reqs[0].clone();
    oversized.id = 1;
    oversized.prompt = vec![1; sess.cfg.seq_len + 1];
    let mut zero_budget = reqs[0].clone();
    zero_budget.id = 2;
    zero_budget.max_new_tokens = 0;
    let workload =
        vec![empty, oversized, zero_budget, reqs[0].clone()];

    let cfg = cfg_for(0, 0, 0);
    let mut rejected: Vec<(usize, String)> = Vec::new();
    let mut done: Vec<CompletedRequest> = Vec::new();
    let mut source = WorkloadSource::new(&workload, 0.0);
    let mut sink = |ev: DecodeEvent| match ev {
        DecodeEvent::Rejected { id, reason } => rejected.push((id, reason)),
        DecodeEvent::Done(c) => done.push(c),
        _ => {}
    };
    let counters = run_engine(&sess, &params, &Engine::Dense, None, &cfg,
                              &mut source, &mut sink)
        .expect("one bad request must not tear down the engine loop");

    assert_eq!(counters.requests_rejected, 3);
    assert_eq!(rejected.len(), 3);
    let reason_of = |id: usize| -> String {
        rejected.iter().find(|(i, _)| *i == id).expect("rejection").1.clone()
    };
    assert!(reason_of(0).contains("empty prompt"), "{}", reason_of(0));
    assert!(reason_of(1).contains("exceeds seq_len"), "{}", reason_of(1));
    assert!(reason_of(2).contains("max_new_tokens"), "{}", reason_of(2));
    // the valid request behind the malformed ones still completed in full
    assert_eq!(done.len(), 1);
    assert_eq!(done[0].id, 3);
    assert_eq!(done[0].tokens.len(), 3);

    // the offline wrapper's contract is unchanged: it validates the whole
    // workload up front and hard-errors before any compute
    let err = run_decode(&sess, &params, &Engine::Dense, &workload, &cfg)
        .expect_err("offline wrapper must reject the workload up front");
    assert!(format!("{err}").contains("empty prompt"), "{err}");
}
