//! Serial-vs-parallel equivalence harness: the `exec` worker pool must be
//! invisible in the numbers.  For thread counts {1, 2, 4} the parallel
//! matmul kernel, the band-partitioned `gram`, `decompose_all`, and a full
//! `compress_zs` run (including one correction iteration, which exercises
//! the native backward pass and its parallel projections) must produce
//! BIT-IDENTICAL results — ranks, `stored_params`, replacement matrices,
//! factors.  The whole harness re-runs on the portable kernel backend in
//! ci.sh's `PALLAS_NO_SIMD=1` lane (backend bit-identity itself is gated
//! by `rust/tests/kernel_equiv.rs`).
//!
//! Everything lives in ONE test function: `exec::set_threads` is process
//! global, and the harness would otherwise race against itself.

use zs_svd::compress::pipeline::decompose_all;
use zs_svd::compress::{compress_zs, Calibration, ZsOpts};
use zs_svd::data;
use zs_svd::exec;
use zs_svd::linalg::{gram, matmul, matmul_serial};
use zs_svd::model::init::init_params;
use zs_svd::runtime::session::Session;
use zs_svd::runtime::Runtime;
use zs_svd::tensor::Mat;
use zs_svd::util::rng::Rng;

#[test]
fn serial_and_parallel_paths_are_bit_identical() {
    let rt = Runtime::load_default().unwrap();
    let sess = Session::new(&rt, "tiny");
    let mut rng = Rng::new(31);
    let params = init_params(&sess.cfg, &mut rng);
    let world = data::default_world();
    let corpus = data::training_corpus("llama", &world);
    // one real batch so the correction iteration (mean_grads) can run
    let mut brng = Rng::new(0xBA7C);
    let batch = corpus.calibration_batch(&mut brng, sess.cfg.batch,
                                         sess.cfg.seq_len);
    let calib = Calibration::synthetic(&sess.cfg, 0xE9_01, vec![batch]);

    // ---- parallel matmul kernel vs the serial reference ----
    let a = Mat::randn(&mut rng, 352, 256, 1.0);
    let b = Mat::randn(&mut rng, 256, 300, 1.0);
    let reference = matmul_serial(&a, &b);
    for t in [1usize, 2, 4] {
        exec::set_threads(t);
        assert_eq!(matmul(&a, &b), reference, "matmul at {t} threads");
    }

    // ---- gram: fixed row-band fan-out + pairwise tree reduction.  The
    // band size is a constant, so the combination tree — and the bits —
    // depend only on the row count, never the thread count ----
    let gx = Mat::randn(&mut rng, 700, 96, 1.0); // spans several 128-row bands
    exec::set_threads(1);
    let gram_ref = gram(&gx);
    for t in [1usize, 2, 4] {
        exec::set_threads(t);
        assert_eq!(gram(&gx), gram_ref, "gram at {t} threads");
    }

    // ---- decompose_all ----
    exec::set_threads(1);
    let serial = decompose_all(&sess, &params, &calib);
    for t in [2usize, 4] {
        exec::set_threads(t);
        let par = decompose_all(&sess, &params, &calib);
        assert_eq!(par.len(), serial.len());
        for (p, s) in par.iter().zip(&serial) {
            assert_eq!(p.name, s.name, "{t} threads");
            assert_eq!(p.lambda, s.lambda, "{}: lambda at {t} threads", p.name);
            assert_eq!(p.s, s.s, "{}: whitening factor at {t} threads", p.name);
            assert_eq!(p.svd.sigma, s.svd.sigma, "{}: sigma at {t} threads", p.name);
            assert_eq!(p.svd.u, s.svd.u, "{}: U at {t} threads", p.name);
            assert_eq!(p.svd.v, s.svd.v, "{}: V at {t} threads", p.name);
            assert_eq!(p.dl, s.dl, "{}: dl at {t} threads", p.name);
        }
    }

    // ---- calibration passes: batch-level fan-out + fixed-order tree
    // reduction must be bit-identical across thread counts ----
    let mut crng = Rng::new(0xCA1B);
    let cal_batches: Vec<_> = (0..3)
        .map(|_| corpus.calibration_batch(&mut crng, sess.cfg.batch,
                                          sess.cfg.seq_len))
        .collect();
    exec::set_threads(1);
    let m_ref = sess.accumulate_moments(&params, &cal_batches).unwrap();
    let (l_ref, g_ref, f_ref) = sess.mean_grads(&params, &cal_batches).unwrap();
    for t in [2usize, 4] {
        exec::set_threads(t);
        let m = sess.accumulate_moments(&params, &cal_batches).unwrap();
        assert_eq!(m.len(), m_ref.len());
        for (a, b2) in m.iter().zip(&m_ref) {
            assert_eq!(a.site, b2.site);
            assert_eq!(a.xx, b2.xx, "{}: moments xx at {t} threads", a.site);
            assert_eq!(a.sum, b2.sum, "{}: moments sum at {t} threads", a.site);
            assert_eq!(a.abssum, b2.abssum,
                       "{}: moments abssum at {t} threads", a.site);
            assert_eq!(a.count, b2.count);
        }
        let (l, g, f) = sess.mean_grads(&params, &cal_batches).unwrap();
        assert_eq!(l.to_bits(), l_ref.to_bits(), "loss at {t} threads");
        assert_eq!(g, g_ref, "mean grads at {t} threads");
        assert_eq!(f, f_ref, "fisher at {t} threads");
    }

    // ---- full compress_zs, including one correction iteration (native
    // backward pass + parallel projections) ----
    let opts = ZsOpts { correction_iters: 1, ..ZsOpts::new(0.5) };
    exec::set_threads(1);
    let plan_serial = compress_zs(&sess, &params, &calib, &opts).unwrap();
    for t in [2usize, 4] {
        exec::set_threads(t);
        let plan = compress_zs(&sess, &params, &calib, &opts).unwrap();
        assert_eq!(plan.ranks(), plan_serial.ranks(), "ranks at {t} threads");
        assert_eq!(plan.targets.len(), plan_serial.targets.len());
        for (x, y) in plan.targets.iter().zip(&plan_serial.targets) {
            assert_eq!(x.name, y.name);
            assert_eq!(x.dense, y.dense, "{}: dense flag at {t} threads", x.name);
            assert_eq!(x.stored_params, y.stored_params,
                       "{}: stored_params at {t} threads", x.name);
            assert_eq!(x.replacement, y.replacement,
                       "{}: replacement differs at {t} threads", x.name);
            assert_eq!(x.factors, y.factors,
                       "{}: factors differ at {t} threads", x.name);
        }
    }
    exec::set_threads(0);
}
