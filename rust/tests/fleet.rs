//! Fleet serving gates: the supervised multi-worker router must be
//! invisible to correctness.
//!
//! 1. **Routed bit-match** — a fleet of real worker *processes* (spawned
//!    from this build's own `zs-svd` binary, each booting the same packed
//!    artifact) streams generations that reproduce the offline
//!    `decode::run_decode` reference BIT-EXACTLY, swept over worker counts
//!    {1, 2, 4} × worker thread counts {1, 4} × speculation depths {0, 2}.
//!    One offline reference serves the whole sweep: tokens depend only on
//!    (weights, prompt, temperature, seed).
//! 2. **Kill −9 mid-stream** — a worker killed while streaming produces a
//!    structured `worker_failed` error (never a silent hang), the
//!    supervisor restarts it from the same artifact, and the re-issued
//!    identical request bit-matches the offline reference.
//! 3. **Graceful degradation** — with one of two workers killed, traffic
//!    keeps completing (client retry policy absorbs the structured
//!    errors) and still bit-matches.
//! 4. **Partial reload** — a fleet-wide `reload` where one worker's store
//!    is corrupt swaps the healthy worker, leaves the other on its old
//!    plan, and reports exactly which workers swapped; a follow-up valid
//!    reload converges the fleet, after which generations bit-match the
//!    new plan's offline reference.
//! 5. **Slow-reader isolation + control plane** — a client that never
//!    reads its stream does not block other connections (which still
//!    bit-match), and the router answers `hello`/`ping` with version
//!    skew failing loudly.

use std::collections::BTreeMap;
use std::net::SocketAddr;
use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::time::{Duration, Instant};

use zs_svd::artifact::{self, install, pack, ChunkStore};
use zs_svd::artifact::store::read_manifest_file;
use zs_svd::decode::{run_decode, DecodeConfig, DecodeRequest};
use zs_svd::fleet::{run_fleet, FleetStats, RouterConfig};
use zs_svd::model::init::init_params;
use zs_svd::model::ParamStore;
use zs_svd::runtime::session::Session;
use zs_svd::runtime::Runtime;
use zs_svd::serve::Engine;
use zs_svd::server::protocol::{Event, Request, ERR_BAD_REQUEST,
                               ERR_WORKER_FAILED, PROTO_VERSION};
use zs_svd::server::{generate_with_retries, Client, GenerateOutcome,
                     GenerateReq, ReloadOutcome, RetryPolicy};
use zs_svd::tensor::Mat;
use zs_svd::util::rng::Rng;

const CLIENTS: usize = 4;
const PER_CLIENT: usize = 2;
const PROMPT_LEN: usize = 8;
const MAX_NEW: usize = 6;

fn worker_binary() -> PathBuf {
    PathBuf::from(env!("CARGO_BIN_EXE_zs-svd"))
}

fn tmp_root(tag: &str) -> PathBuf {
    let d = std::env::temp_dir()
        .join(format!("zs_fleet_gate_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&d).ok();
    d
}

/// Deterministic prompt for logical request `k` — identical on the wire
/// and in the offline reference.
fn prompt_for(k: usize, vocab: usize) -> Vec<i32> {
    let mut rng = Rng::new(0xF1EE7 ^ (k as u64));
    (0..PROMPT_LEN).map(|_| rng.range(1, vocab) as i32).collect()
}

/// Alternate greedy and explicit-seed temperature sampling across the
/// logical request ids, so both sampling paths ride through the router.
fn sampling_for(k: usize) -> (Option<f32>, Option<u64>) {
    if k % 2 == 0 {
        (Some(0.0), None)
    } else {
        (Some(0.7), Some(7_000 + k as u64))
    }
}

/// Pack a complete serving artifact (params + low-rank engine + drafter)
/// into a fresh store and return (store root, manifest path).
fn packed_lowrank(tag: &str) -> (PathBuf, PathBuf) {
    let rt = Runtime::load_default().unwrap();
    let sess = Session::new(&rt, "tiny");
    let mut rng = Rng::new(0xF1EE7);
    let params = init_params(&sess.cfg, &mut rng);
    let lr_tag = sess.cfg.lowrank.keys().next().expect("a lowrank tag")
        .clone();
    let lm = &sess.cfg.lowrank[&lr_tag];
    let factors: BTreeMap<String, (Mat, Mat)> = sess.cfg.targets.iter()
        .map(|t| {
            let (m, n) = t.shape;
            let k = lm.ranks[&t.name];
            (t.name.clone(),
             (Mat::randn(&mut rng, m, k, 0.05),
              Mat::randn(&mut rng, k, n, 0.05)))
        })
        .collect();
    let engine = Engine::Lowrank { tag: lr_tag.clone(),
                                   factors: factors.clone() };
    let drafter = Engine::Lowrank { tag: lr_tag, factors };
    let root = tmp_root(tag);
    let manifest = pack(&sess.cfg, &params, &engine, Some(&drafter), &root,
                        "fleet-a").expect("pack");
    (root, manifest)
}

/// Offline single-process reference for logical requests `0..n`, computed
/// on the artifact exactly as a worker loads it.
fn offline_reference(manifest: &Path, n: usize, max_new: usize)
                     -> Vec<Vec<i32>> {
    let rt = Runtime::load_default().unwrap();
    let bundle = artifact::load(manifest).expect("bundle loads");
    let sess = Session::new(&rt, &bundle.model);
    let reqs: Vec<DecodeRequest> = (0..n)
        .map(|k| {
            let (temperature, seed) = sampling_for(k);
            DecodeRequest { id: k, prompt: prompt_for(k, sess.cfg.vocab),
                            max_new_tokens: max_new, temperature, seed }
        })
        .collect();
    let dc = DecodeConfig { max_slots: 3, max_new_tokens: max_new,
                            temperature: 0.0, seed: 9, arrival_steps: 0.0,
                            prefill_chunk: 0, speculate_k: 0,
                            ..DecodeConfig::default() };
    let (_, done) = run_decode(&sess, &bundle.params, &bundle.engine, &reqs,
                               &dc).expect("offline decode");
    done.into_iter().map(|c| c.tokens).collect()
}

struct Fleet {
    addr: SocketAddr,
    handle: std::thread::JoinHandle<std::io::Result<FleetStats>>,
}

/// Boot a fleet on an ephemeral port and wait for the bound address (the
/// router listens immediately; early requests queue until workers pass
/// their handshake).
fn start_fleet(manifest: &Path, workers: usize, worker_args: &[&str],
               tweak: impl FnOnce(&mut RouterConfig)) -> Fleet {
    let mut cfg = RouterConfig::new(
        "127.0.0.1:0", workers,
        vec![manifest.to_str().expect("utf8").to_string()]);
    cfg.program = worker_binary();
    cfg.worker_args = worker_args.iter().map(|s| s.to_string()).collect();
    // fast health verdicts keep the fault-injection lanes snappy without
    // false positives (workers answer pings from a dedicated reader)
    cfg.heartbeat_ms = 100;
    cfg.health_timeout_ms = 2_000;
    tweak(&mut cfg);
    let (tx, rx) = mpsc::channel::<SocketAddr>();
    let handle = std::thread::spawn(move || {
        run_fleet(cfg, move |a| { tx.send(a).expect("report addr"); })
    });
    let addr = rx.recv_timeout(Duration::from_secs(60)).expect("fleet bound");
    Fleet { addr, handle }
}

/// Drain the fleet via a protocol `shutdown` and return its stats.
fn stop_fleet(f: Fleet) -> FleetStats {
    let mut c = Client::connect(f.addr).expect("connect for shutdown");
    c.shutdown_server().expect("shutdown");
    f.handle.join().expect("fleet thread").expect("fleet run")
}

/// Per-worker (pid, healthy, restarts) out of the fleet metrics snapshot.
fn worker_info(c: &mut Client, idx: usize) -> (u64, bool, u64) {
    let snap = c.metrics().expect("metrics");
    let ws = snap.get("workers").and_then(|w| w.as_arr())
        .expect("fleet snapshot carries a workers array");
    let w = &ws[idx];
    (w.usize_or("pid", 0) as u64, w.bool_or("healthy", false),
     w.usize_or("restarts", 0) as u64)
}

/// Block until worker `idx` reports healthy (fresh incarnation serving).
fn wait_healthy(addr: SocketAddr, idx: usize, min_restarts: u64) -> u64 {
    let mut c = Client::connect(addr).expect("connect");
    let deadline = Instant::now() + Duration::from_secs(60);
    loop {
        let (pid, healthy, restarts) = worker_info(&mut c, idx);
        if healthy && pid != 0 && restarts >= min_restarts {
            return pid;
        }
        assert!(Instant::now() < deadline,
                "worker {idx} never became healthy (restarts {restarts}, \
                 want ≥ {min_restarts})");
        std::thread::sleep(Duration::from_millis(50));
    }
}

fn kill9(pid: u64) {
    let _ = std::process::Command::new("kill")
        .args(["-9", &pid.to_string()])
        .status();
}

/// Drive `CLIENTS` concurrent connections through the router and collect
/// each logical request's streamed tokens.
fn fleet_collect(addr: SocketAddr, vocab: usize) -> Vec<(usize, Vec<i32>)> {
    let mut collected: Vec<(usize, Vec<i32>)> = Vec::new();
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..CLIENTS)
            .map(|c| {
                s.spawn(move || {
                    let mut cl = Client::connect(addr).expect("connect");
                    let mut out = Vec::new();
                    for i in 0..PER_CLIENT {
                        let k = c * PER_CLIENT + i;
                        let (temperature, seed) = sampling_for(k);
                        let g = GenerateReq {
                            id: k as u64,
                            prompt: prompt_for(k, vocab),
                            max_new_tokens: MAX_NEW,
                            temperature,
                            seed,
                        };
                        match cl.run_generate(&g).expect("generate") {
                            GenerateOutcome::Done(r) => {
                                assert_eq!(r.tokens.len(), MAX_NEW,
                                           "request {k} budget");
                                out.push((k, r.tokens));
                            }
                            GenerateOutcome::Rejected { code, message, .. }
                            => panic!("request {k} rejected: {code} \
                                       ({message})"),
                        }
                    }
                    out
                })
            })
            .collect();
        for h in handles {
            collected.extend(h.join().expect("client thread"));
        }
    });
    collected.sort_by_key(|(k, _)| *k);
    collected
}

#[test]
fn routed_generations_bitmatch_single_process_reference() {
    let (root, manifest) = packed_lowrank("bitmatch");
    let rt = Runtime::load_default().unwrap();
    let vocab = Session::new(&rt, "tiny").cfg.vocab;
    // one offline reference for the whole sweep: worker count, worker
    // threads, and speculation are all forbidden from touching tokens
    let offline = offline_reference(&manifest, CLIENTS * PER_CLIENT,
                                    MAX_NEW);

    for workers in [1usize, 2, 4] {
        for threads in ["1", "4"] {
            for speculate_k in ["0", "2"] {
                let fleet = start_fleet(
                    &manifest, workers,
                    &["--threads", threads, "--speculate-k", speculate_k],
                    |_| {});
                let served = fleet_collect(fleet.addr, vocab);
                assert_eq!(served.len(), CLIENTS * PER_CLIENT);
                for (k, tokens) in &served {
                    assert_eq!(
                        tokens, &offline[*k],
                        "request {k} via {workers} worker(s) @ {threads} \
                         thread(s), speculate_k {speculate_k}: routed \
                         generation must bit-match the single-process \
                         reference");
                }
                let stats = stop_fleet(fleet);
                assert_eq!(stats.requests_routed as usize,
                           CLIENTS * PER_CLIENT);
                assert_eq!(stats.worker_restarts, 0,
                           "no faults were injected");
            }
        }
    }
    std::fs::remove_dir_all(&root).ok();
}

#[test]
fn kill_dash_nine_mid_stream_fails_structured_then_restarts_and_bitmatches() {
    const KILL_BUDGET: usize = 48; // long stream: a wide window to land in
    let (root, manifest) = packed_lowrank("kill");
    let rt = Runtime::load_default().unwrap();
    let vocab = Session::new(&rt, "tiny").cfg.vocab;
    let offline = offline_reference(&manifest, 8, KILL_BUDGET);
    let g = GenerateReq { id: 7, prompt: prompt_for(7, vocab),
                          max_new_tokens: KILL_BUDGET,
                          temperature: Some(0.0), seed: None };

    let fleet = start_fleet(&manifest, 1, &["--threads", "1"], |_| {});
    let addr = fleet.addr;
    let mut ctrl = Client::connect(addr).expect("control connect");

    // hammer until a SIGKILL lands mid-stream: the kill races the (fast)
    // generation, so retry with a fresh incarnation pid until the client
    // observes the structured failure
    let deadline = Instant::now() + Duration::from_secs(120);
    let mut kills = 0u64;
    loop {
        assert!(Instant::now() < deadline,
                "kill -9 never landed mid-stream after {kills} attempts");
        let (pid, healthy, _) = worker_info(&mut ctrl, 0);
        if !healthy || pid == 0 {
            std::thread::sleep(Duration::from_millis(50));
            continue;
        }
        let killer = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            kill9(pid);
        });
        kills += 1;
        let mut cl = Client::connect(addr).expect("connect");
        let outcome = cl.run_generate(&g);
        killer.join().expect("killer thread");
        match outcome {
            Ok(GenerateOutcome::Rejected { code, message, .. }) => {
                assert_eq!(code, ERR_WORKER_FAILED,
                           "a killed worker must surface as worker_failed, \
                            got {code}: {message}");
                break; // the structured mid-stream failure we wanted
            }
            Ok(GenerateOutcome::Done(r)) => {
                // the generation outran the kill — even so, it bit-matches
                assert_eq!(r.tokens, offline[7]);
            }
            Err(_) => {} // transport race with the dying worker: try again
        }
    }

    // automatic restart from the same artifact...
    wait_healthy(addr, 0, 1);
    // ...and the re-issued IDENTICAL request bit-matches the reference
    let mut cl = Client::connect(addr).expect("connect after restart");
    match cl.run_generate(&g).expect("post-restart generate") {
        GenerateOutcome::Done(r) => assert_eq!(
            r.tokens, offline[7],
            "post-restart generation must bit-match the offline reference"),
        GenerateOutcome::Rejected { code, message, .. } => {
            panic!("post-restart request rejected: {code} ({message})");
        }
    }

    let stats = stop_fleet(fleet);
    assert!(stats.worker_restarts >= 1,
            "the supervisor must have restarted the killed worker");
    assert!(stats.worker_failures >= 1);
    std::fs::remove_dir_all(&root).ok();
}

#[test]
fn two_worker_fleet_degrades_gracefully_when_one_dies() {
    let (root, manifest) = packed_lowrank("degrade");
    let rt = Runtime::load_default().unwrap();
    let vocab = Session::new(&rt, "tiny").cfg.vocab;
    let offline = offline_reference(&manifest, CLIENTS * PER_CLIENT,
                                    MAX_NEW);

    let fleet = start_fleet(&manifest, 2, &["--threads", "1"], |_| {});
    let addr = fleet.addr;
    wait_healthy(addr, 0, 0);
    wait_healthy(addr, 1, 0);

    // kill worker 0; traffic continues on worker 1 while the supervisor
    // respawns — the client retry policy absorbs any worker_failed error
    // from requests caught mid-flight
    let mut ctrl = Client::connect(addr).expect("control connect");
    let (pid0, _, _) = worker_info(&mut ctrl, 0);
    kill9(pid0);
    let policy = RetryPolicy { retries: 6, base_ms: 20, max_ms: 500,
                               seed: 0xDE6 };
    for k in 0..CLIENTS * PER_CLIENT {
        let (temperature, seed) = sampling_for(k);
        let g = GenerateReq { id: k as u64, prompt: prompt_for(k, vocab),
                              max_new_tokens: MAX_NEW, temperature, seed };
        match generate_with_retries(addr, &g, &policy)
            .expect("degraded generate")
        {
            GenerateOutcome::Done(r) => assert_eq!(
                r.tokens, offline[k],
                "request {k} during degradation must still bit-match"),
            GenerateOutcome::Rejected { code, message, .. } => {
                panic!("request {k} rejected after retries: {code} \
                        ({message})");
            }
        }
    }
    // the killed worker comes back on its own
    wait_healthy(addr, 0, 1);

    let stats = stop_fleet(fleet);
    assert!(stats.worker_restarts >= 1);
    std::fs::remove_dir_all(&root).ok();
}

#[test]
fn partial_reload_swaps_only_verified_workers_and_reports_precisely() {
    let (root_a, manifest_a) = packed_lowrank("reload_a");
    let ref_a = offline_reference(&manifest_a, 4, MAX_NEW);

    // plan B: a dense artifact packed beside A, plus a second copy of B
    // whose store is then corrupted (worker 1's reload must fail verify)
    let rt = Runtime::load_default().unwrap();
    let sess = Session::new(&rt, "tiny");
    let vocab = sess.cfg.vocab;
    let params: ParamStore = {
        let mut rng = Rng::new(0xB0B);
        init_params(&sess.cfg, &mut rng)
    };
    let manifest_b = pack(&sess.cfg, &params, &Engine::Dense, None, &root_a,
                          "fleet-b").expect("pack B");
    let ref_b = offline_reference(&manifest_b, 4, MAX_NEW);
    let root_bad = tmp_root("reload_bad");
    let manifest_bad = install(&manifest_b, &root_bad, "fleet-b")
        .expect("install B copy");
    {
        // flip one byte in the middle of the copy's first chunk: checksum
        // verification at load must reject it
        let m = read_manifest_file(&manifest_bad).expect("manifest");
        let store = ChunkStore::open(&root_bad).expect("store");
        let path = store.chunk_path(&m.records[0].id);
        let mut bytes = std::fs::read(&path).expect("chunk");
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x01;
        std::fs::write(&path, bytes).expect("corrupt");
    }

    let fleet = start_fleet(&manifest_a, 2, &["--threads", "1"], |_| {});
    let addr = fleet.addr;
    wait_healthy(addr, 0, 0);
    wait_healthy(addr, 1, 0);
    let mut cl = Client::connect(addr).expect("connect");

    // per-worker fan-out: worker 0 gets the good B, worker 1 the corrupt
    // copy — the fleet must end up split and SAY SO
    let spec = format!("{},{}",
                       manifest_b.to_str().expect("utf8"),
                       manifest_bad.to_str().expect("utf8"));
    match cl.reload(&spec).expect("reload io") {
        ReloadOutcome::Rejected { code, message } => {
            assert_eq!(code, "reload_failed");
            assert!(message.contains("swapped [worker 0]"),
                    "must name the swapped worker: {message}");
            assert!(message.contains("worker 1"),
                    "must name the failed worker: {message}");
        }
        ReloadOutcome::Swapped { engine, .. } => {
            panic!("corrupt store cannot verify, yet fleet swapped to \
                    {engine}");
        }
    }

    // split-brain window: every generation is served by SOME worker's
    // plan, so each must bit-match exactly one of the two references
    for k in 0..4usize {
        let (temperature, seed) = sampling_for(k);
        let g = GenerateReq { id: k as u64, prompt: prompt_for(k, vocab),
                              max_new_tokens: MAX_NEW, temperature, seed };
        let mut c = Client::connect(addr).expect("connect");
        match c.run_generate(&g).expect("split generate") {
            GenerateOutcome::Done(r) => assert!(
                r.tokens == ref_a[k] || r.tokens == ref_b[k],
                "request {k} matches neither plan A nor plan B"),
            GenerateOutcome::Rejected { code, message, .. } => {
                panic!("request {k} rejected: {code} ({message})");
            }
        }
    }

    // a valid fleet-wide path converges both workers onto B...
    match cl.reload(manifest_b.to_str().expect("utf8")).expect("reload io") {
        ReloadOutcome::Swapped { engine, .. } => {
            assert!(engine.contains("fleet["), "router label: {engine}");
        }
        ReloadOutcome::Rejected { code, message } => {
            panic!("healthy reload rejected: {code} ({message})");
        }
    }
    // ...after which every request bit-matches plan B, whoever serves it
    let served = fleet_collect(addr, vocab);
    let ref_b_full = offline_reference(&manifest_b, CLIENTS * PER_CLIENT,
                                       MAX_NEW);
    for (k, tokens) in &served {
        assert_eq!(tokens, &ref_b_full[*k],
                   "request {k} after converged reload must bit-match B");
    }

    let _ = stop_fleet(fleet);
    std::fs::remove_dir_all(&root_a).ok();
    std::fs::remove_dir_all(&root_bad).ok();
}

#[test]
fn slow_reader_is_isolated_and_control_plane_answers() {
    let (root, manifest) = packed_lowrank("slow");
    let rt = Runtime::load_default().unwrap();
    let vocab = Session::new(&rt, "tiny").cfg.vocab;
    let offline = offline_reference(&manifest, CLIENTS * PER_CLIENT,
                                    MAX_NEW);

    let fleet = start_fleet(&manifest, 1, &["--threads", "1"], |_| {});
    let addr = fleet.addr;

    // version handshake: matching proto answered with the fleet label...
    let mut cl = Client::connect(addr).expect("connect");
    let (proto, _version, engine) = cl.hello().expect("hello");
    assert_eq!(proto, PROTO_VERSION);
    assert!(engine.starts_with("fleet["),
            "router must identify as a fleet, got `{engine}`");
    cl.ping(0xC0FFEE).expect("ping");
    // ...and version skew fails loudly instead of garbling mid-stream
    cl.send(&Request::Hello { proto: 99 }).expect("send skewed hello");
    match cl.next_event().expect("reply").expect("open stream") {
        Event::Error { code, message, .. } => {
            assert_eq!(code, ERR_BAD_REQUEST);
            assert!(message.contains("proto"), "message: {message}");
        }
        other => panic!("skewed hello must error, got {other:?}"),
    }

    // a stalled reader: sends one generate, then never reads its stream
    // while other connections do real work
    let stalled = Client::connect(addr).expect("stalled connect");
    {
        let mut s = stalled;
        s.send(&Request::Generate(GenerateReq {
            id: 999, prompt: prompt_for(0, vocab),
            max_new_tokens: MAX_NEW, temperature: Some(0.0), seed: None,
        })).expect("stalled send");
        // fast clients must be unaffected and still bit-match
        let served = fleet_collect(addr, vocab);
        for (k, tokens) in &served {
            assert_eq!(tokens, &offline[*k],
                       "request {k} with a stalled sibling connection");
        }
        drop(s); // the stalled connection goes away unread
    }

    let _ = stop_fleet(fleet);
    std::fs::remove_dir_all(&root).ok();
}
