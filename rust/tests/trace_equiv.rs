//! Observe-only gate for the tracing layer (`zs_svd::obs`).
//!
//! The observability subsystem may record anything it likes, but it must
//! never *change* anything: compression plans, decode tokens, and
//! speculative generations have to be BIT-IDENTICAL with tracing on or
//! off, at every thread count.  This binary proves that, and also checks
//! the exports are well-formed:
//!
//! * ZS-SVD compression produces the same plan (ranks, dense-keep
//!   decisions, replacement matrices bit-for-bit) traced and untraced, and
//!   the traced run additionally leaves phase spans in the ring while the
//!   always-on compress report is produced either way;
//! * continuous-batching decode and speculative self-decode generate the
//!   same tokens traced and untraced at threads {1, 4}, while the traced
//!   runs accumulate the per-phase counters the bench harnesses consume;
//! * `CompletedRequest.prefill_ms` / `decode_ms` partition the end-to-end
//!   latency exactly (queue + prefill + decode == e2e), tracing or not;
//! * the chrome-trace export parses with the repo's own `util::json`,
//!   every span event carries the Trace Event Format keys, and the wire
//!   `snapshot_json` respects its `max_events` cap;
//! * with tracing off the ring stays empty and gated counters stay zero.
//!
//! Everything lives in ONE test function: `obs::set_enabled`,
//! `obs::reset`, and `exec::set_threads` are process-global (same pattern
//! as the sweeps in `decode_parity.rs`).  Kernel backends: ci.sh re-runs
//! this gate under `PALLAS_NO_SIMD=1`, so the observe-only contract is
//! proven on both the SIMD and the portable backend.

use std::collections::BTreeMap;
use std::path::PathBuf;

use zs_svd::compress::{calibrate, compress_zs, CompressionPlan, ZsOpts};
use zs_svd::data;
use zs_svd::decode::{run_decode, run_decode_speculative, synth_requests,
                     DecodeConfig};
use zs_svd::exec;
use zs_svd::model::init::init_params;
use zs_svd::obs;
use zs_svd::runtime::session::Session;
use zs_svd::runtime::Runtime;
use zs_svd::serve::Engine;
use zs_svd::tensor::Mat;
use zs_svd::util::json;
use zs_svd::util::rng::Rng;

/// Uniform-rank random factors matching the artifact ranks of `tag` — the
/// same helper `decode_parity.rs` uses for its drafter engine.
fn synthetic_factors(sess: &Session, tag: &str, rng: &mut Rng)
                     -> BTreeMap<String, (Mat, Mat)> {
    let lm = sess.cfg.lowrank.get(tag).expect("artifact tag");
    sess.cfg
        .targets
        .iter()
        .map(|t| {
            let (m, n) = t.shape;
            let k = lm.ranks[&t.name];
            (t.name.clone(),
             (Mat::randn(rng, m, k, 0.05), Mat::randn(rng, k, n, 0.05)))
        })
        .collect()
}

/// Everything decision-relevant in a plan, with replacement weights as raw
/// f32 bit patterns so "identical" means identical, not approximately so.
fn plan_key(p: &CompressionPlan)
            -> Vec<(String, usize, bool, Vec<u32>)> {
    p.targets
        .iter()
        .map(|t| (t.name.clone(), t.rank, t.dense,
                  t.replacement.data.iter().map(|v| v.to_bits()).collect()))
        .collect()
}

#[test]
fn tracing_is_observe_only_and_exports_are_wellformed() {
    let rt = Runtime::load_default().unwrap();
    let sess = Session::new(&rt, "tiny");
    let mut rng = Rng::new(0x7ACE);
    let params = init_params(&sess.cfg, &mut rng);

    // ---- compression: traced == untraced, bit for bit -------------------
    let world = data::default_world();
    let corpus = data::training_corpus("llama", &world);
    let calib = calibrate(&sess, &params, &corpus, 2, 0xCA11B).unwrap();

    obs::set_enabled(false);
    obs::reset();
    let plain = compress_zs(&sess, &params, &calib, &ZsOpts::new(0.4))
        .unwrap();
    // the compress report is always-on: it exists even with tracing off...
    let rep_off = obs::report("compress").expect("report without tracing");
    // ...but the gated phase spans do not
    assert_eq!(obs::snapshot_json(8).usize_or("events_total", 99), 0,
               "tracing off must leave the event ring empty");

    obs::set_enabled(true);
    obs::reset();
    let traced = compress_zs(&sess, &params, &calib, &ZsOpts::new(0.4))
        .unwrap();
    assert_eq!(plan_key(&plain), plan_key(&traced),
               "tracing changed the compression plan");

    // the traced run leaves the compress.* phase spans in the ring
    let snap = obs::snapshot_json(256);
    let names: Vec<String> = snap.get("events").and_then(|e| e.as_arr())
        .expect("events array")
        .iter()
        .map(|e| e.str_or("name", ""))
        .collect();
    for want in ["compress.decompose", "compress.select", "compress.build"] {
        assert!(names.iter().any(|n| n == want),
                "missing phase span `{want}` in {names:?}");
    }

    // the report mirrors the plan: one record per target, with the
    // per-matrix fields the paper's selection story is told in
    let rep = obs::report("compress").expect("report with tracing");
    assert_eq!(rep.str_or("type", ""), "compress_report");
    let targets = rep.get("targets").and_then(|t| t.as_arr())
        .expect("targets array");
    assert_eq!(targets.len(), traced.targets.len());
    for t in targets {
        for key in ["name", "m", "n", "rank", "removed", "dl_removed",
                    "keep_dense"] {
            assert!(t.get(key).is_some(), "target record missing `{key}`");
        }
    }
    let traj = rep.get("trajectory").and_then(|t| t.as_arr())
        .expect("trajectory array");
    assert!(!traj.is_empty(), "a 0.4-ratio run removes components");
    assert!(traj.len() <= zs_svd::compress::selection::TRAJECTORY_CAP);
    // both runs stashed the same selection outcome
    assert_eq!(rep_off.get("selection").map(|s| s.to_string()),
               rep.get("selection").map(|s| s.to_string()));

    // ---- decode + speculation: same tokens, threads {1, 4} --------------
    let drafter = Engine::Lowrank {
        tag: "60".into(),
        factors: synthetic_factors(&sess, "60", &mut rng),
    };
    let reqs = synth_requests(&sess.cfg, 6, 10, 5, 0xF00D);
    let cfg_for = |k: usize| DecodeConfig {
        max_slots: 3, max_new_tokens: 5, temperature: 0.0, seed: 11,
        arrival_steps: 0.0, prefill_chunk: 4, speculate_k: k,
        ..DecodeConfig::default()
    };
    let tokens_of = |done: &[zs_svd::decode::CompletedRequest]|
                     -> Vec<Vec<i32>> {
        done.iter().map(|c| c.tokens.clone()).collect()
    };

    for threads in [1usize, 4] {
        exec::set_threads(threads);

        obs::set_enabled(false);
        obs::reset();
        let (_, off) = run_decode(&sess, &params, &Engine::Dense, &reqs,
                                  &cfg_for(0)).unwrap();
        assert_eq!(obs::counter("phase.decode_ns"), 0,
                   "gated counters must not tick with tracing off");

        obs::set_enabled(true);
        obs::reset();
        let (_, on) = run_decode(&sess, &params, &Engine::Dense, &reqs,
                                 &cfg_for(0)).unwrap();
        assert_eq!(tokens_of(&off), tokens_of(&on),
                   "tracing changed decode tokens @ {threads} threads");
        // the per-phase counters the bench breakdowns consume ticked
        assert!(obs::counter("phase.prefill_ns") > 0);
        assert!(obs::counter("phase.decode_ns") > 0);
        assert_eq!(obs::counter("sched.requests_done"), reqs.len() as u64);

        // the latency breakdown partitions e2e exactly, traced or not
        for done in [&off, &on] {
            for c in done.iter() {
                assert!(c.prefill_ms >= 0.0 && c.decode_ms >= 0.0);
                let sum = c.queue_ms + c.prefill_ms + c.decode_ms;
                assert!((sum - c.latency_ms).abs() < 1e-6,
                        "queue {} + prefill {} + decode {} != e2e {}",
                        c.queue_ms, c.prefill_ms, c.decode_ms, c.latency_ms);
            }
        }

        // speculative self-decode: drafter + verify under tracing still
        // bit-matches both its own untraced run and plain greedy
        obs::set_enabled(false);
        obs::reset();
        let (_, s_off) = run_decode_speculative(
            &sess, &params, &Engine::Dense, &drafter, &reqs, &cfg_for(2))
            .unwrap();
        obs::set_enabled(true);
        obs::reset();
        let (_, s_on) = run_decode_speculative(
            &sess, &params, &Engine::Dense, &drafter, &reqs, &cfg_for(2))
            .unwrap();
        assert_eq!(tokens_of(&s_off), tokens_of(&s_on),
                   "tracing changed speculative tokens @ {threads} threads");
        assert_eq!(tokens_of(&s_on), tokens_of(&off),
                   "speculation must still bit-match plain greedy");
        assert!(obs::counter("phase.draft_ns") > 0);
        assert!(obs::counter("phase.verify_ns") > 0);
    }

    // ---- export well-formedness (ring still holds the traced run) -------
    let snap = obs::snapshot_json(4);
    assert_eq!(snap.str_or("type", ""), "trace");
    assert!(snap.bool_or("enabled", false));
    let evs = snap.get("events").and_then(|e| e.as_arr()).expect("events");
    assert!(evs.len() <= 4, "snapshot_json must honor max_events");
    assert!(snap.usize_or("events_total", 0) >= evs.len());
    assert!(snap.get("counters").is_some());
    assert!(snap.get("histograms").is_some());
    assert!(snap.get("gauges").is_some());

    let out = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("target").join("trace_equiv_chrome.json");
    obs::write_chrome_trace(&out).unwrap();
    let doc = json::parse_file(&out).expect("chrome trace parses");
    let evs = doc.get("traceEvents").and_then(|e| e.as_arr())
        .expect("traceEvents array");
    assert!(evs.len() > 2, "metadata + at least one span");
    let mut spans = 0usize;
    for e in evs {
        assert!(e.get("name").is_some() && e.get("pid").is_some()
                    && e.get("tid").is_some());
        match e.str_or("ph", "").as_str() {
            "M" => {}
            "X" => {
                assert!(e.get("ts").is_some() && e.get("dur").is_some());
                spans += 1;
            }
            other => panic!("unexpected event phase `{other}`"),
        }
    }
    assert!(spans > 0, "the traced runs must have produced span events");
    // lifecycle spans land on the request track with per-request tids
    let req_spans: Vec<&json::Json> = evs.iter()
        .filter(|e| e.usize_or("pid", 0) as u32 == obs::PID_REQUESTS)
        .collect();
    for want in ["queue", "prefill", "decode"] {
        assert!(req_spans.iter().any(|e| e.str_or("name", "") == want),
                "missing request-track span `{want}`");
    }
    std::fs::remove_file(&out).ok();

    // leave the process the way the other gates expect it
    obs::set_enabled(false);
    obs::reset();
    exec::set_threads(0);
}
