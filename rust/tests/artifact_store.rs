//! Fault-injection gate for the content-addressed artifact store
//! (`zs_svd::artifact`).
//!
//! Every integrity claim the module documents is exercised from the
//! outside, byte-level, against real files:
//!
//! * a single flipped byte in ANY chunk class — meta, a parameter, a U
//!   factor, a V factor, a drafter factor — is detected at load, with a
//!   structured error naming the corrupted chunk's label;
//! * a flipped byte in the manifest itself is detected by its checksum;
//! * a truncated or deleted chunk file is detected, and a failed `install`
//!   leaves **nothing** visible at the destination (no manifest);
//! * an interrupted install resumes — chunks already present and valid are
//!   skipped — and the resumed store ends byte-identical to a clean one.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use zs_svd::artifact::store::read_manifest_file;
use zs_svd::artifact::{install, load, pack, ChunkClass, ChunkStore};
use zs_svd::model::init::init_params;
use zs_svd::model::{ConfigMeta, Manifest, ParamStore};
use zs_svd::serve::Engine;
use zs_svd::tensor::Mat;
use zs_svd::util::rng::Rng;

fn tmp_root(tag: &str) -> PathBuf {
    let d = std::env::temp_dir()
        .join(format!("zs_artifact_gate_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&d).ok();
    d
}

fn tiny_cfg() -> ConfigMeta {
    Manifest::builtin().config("tiny").clone()
}

/// Synthetic but shape-exact serving state for the tiny model: full params
/// plus low-rank target + drafter factors at the tag's baked ranks.
fn synth_state(cfg: &ConfigMeta) -> (ParamStore, Engine, Engine) {
    let tag = cfg.lowrank.keys().next().expect("a lowrank tag").clone();
    let mut rng = Rng::new(0xFA17);
    let params = init_params(cfg, &mut rng);
    let lm = &cfg.lowrank[&tag];
    let factors: BTreeMap<String, (Mat, Mat)> = cfg.targets.iter()
        .map(|t| {
            let (m, n) = t.shape;
            let k = lm.ranks[&t.name];
            (t.name.clone(),
             (Mat::randn(&mut rng, m, k, 0.05),
              Mat::randn(&mut rng, k, n, 0.05)))
        })
        .collect();
    let engine = Engine::Lowrank { tag: tag.clone(),
                                   factors: factors.clone() };
    let drafter = Engine::Lowrank { tag, factors };
    (params, engine, drafter)
}

/// Pack a complete artifact (params + engine + drafter) into a fresh store.
fn packed(tag: &str) -> (PathBuf, PathBuf) {
    let cfg = tiny_cfg();
    let (params, engine, drafter) = synth_state(&cfg);
    let root = tmp_root(tag);
    let manifest = pack(&cfg, &params, &engine, Some(&drafter), &root, "art")
        .expect("pack");
    (root, manifest)
}

/// Path of the chunk file backing the first record of `class` whose label
/// passes `pick`, plus that record's label.
fn chunk_file(root: &Path, manifest: &Path, class: ChunkClass,
              pick: impl Fn(&str) -> bool) -> (PathBuf, String) {
    let m = read_manifest_file(manifest).expect("manifest reads");
    let store = ChunkStore::open(root).expect("store opens");
    let rec = m.records.iter()
        .find(|r| r.class == class && pick(&r.label))
        .unwrap_or_else(|| panic!("no {class:?} record"));
    (store.chunk_path(&rec.id), rec.label.clone())
}

fn flip_byte(path: &Path, at: usize) {
    let mut bytes = std::fs::read(path).expect("read for corruption");
    let i = at.min(bytes.len().saturating_sub(1));
    bytes[i] ^= 0x01;
    std::fs::write(path, bytes).expect("write corrupted");
}

#[test]
fn bit_flip_in_every_chunk_class_is_detected_and_named() {
    let (root, manifest) = packed("bitflip");
    // one representative per chunk class, drafter factors included: the
    // label in the error must point at exactly the corrupted tensor
    let victims = [
        (ChunkClass::Meta, "meta".to_string()),
        (ChunkClass::Param, String::new()),   // first param chunk
        (ChunkClass::FactorU, "u:".to_string()),
        (ChunkClass::FactorV, "v:".to_string()),
        (ChunkClass::FactorU, "du:".to_string()),
        (ChunkClass::FactorV, "dv:".to_string()),
    ];
    for (class, prefix) in victims {
        let (path, label) = chunk_file(&root, &manifest, class,
                                       |l| l.starts_with(&prefix));
        let clean = std::fs::read(&path).expect("clean chunk");
        // flip a byte mid-payload: content hash must catch it
        flip_byte(&path, clean.len() / 2);
        let err = load(&manifest).expect_err("corrupt chunk must not load");
        let msg = format!("{err}");
        assert!(msg.contains(&label),
                "error must name chunk `{label}`: {msg}");
        // restore so the next victim starts from an intact artifact
        std::fs::write(&path, clean).expect("restore");
    }
    // fully restored: the artifact loads again
    load(&manifest).expect("restored artifact loads");
    std::fs::remove_dir_all(&root).ok();
}

#[test]
fn bit_flip_in_the_manifest_is_detected() {
    let (root, manifest) = packed("manifestflip");
    let clean = std::fs::read(&manifest).expect("clean manifest");
    // past the magic so the failure is the checksum, not the format marker
    flip_byte(&manifest, clean.len() - 3);
    let err = load(&manifest).expect_err("corrupt manifest must not load");
    let msg = format!("{err}");
    assert!(msg.contains("manifest"), "error must blame the manifest: {msg}");
    std::fs::write(&manifest, clean).expect("restore");
    load(&manifest).expect("restored artifact loads");
    std::fs::remove_dir_all(&root).ok();
}

#[test]
fn truncated_chunk_is_detected_at_load_and_install() {
    let (root, manifest) = packed("truncate");
    let (path, label) = chunk_file(&root, &manifest, ChunkClass::FactorU,
                                   |l| l.starts_with("u:"));
    let clean = std::fs::read(&path).expect("clean chunk");
    std::fs::write(&path, &clean[..clean.len() - 1]).expect("truncate");

    let msg = format!("{}", load(&manifest).expect_err("load must fail"));
    assert!(msg.contains(&label) && msg.contains("length"),
            "error must name `{label}` and the length mismatch: {msg}");

    // install from the truncated store: fails, and the destination stays
    // empty — no manifest means nothing is visible
    let dst = tmp_root("truncate_dst");
    let msg = format!("{}", install(&manifest, &dst, "art")
        .expect_err("install must fail"));
    assert!(msg.contains(&label), "install error must name `{label}`: {msg}");
    assert!(!dst.join("art.zsar").exists(),
            "a failed install must not commit a manifest");

    std::fs::write(&path, clean).expect("restore");
    std::fs::remove_dir_all(&root).ok();
    std::fs::remove_dir_all(&dst).ok();
}

#[test]
fn deleted_chunk_fails_install_with_nothing_partially_visible() {
    let (root, manifest) = packed("delete");
    let (path, label) = chunk_file(&root, &manifest, ChunkClass::Param,
                                   |_| true);
    std::fs::remove_file(&path).expect("delete chunk");

    let dst = tmp_root("delete_dst");
    let msg = format!("{}", install(&manifest, &dst, "art")
        .expect_err("install must fail on a missing chunk"));
    assert!(msg.contains(&label), "install error must name `{label}`: {msg}");
    assert!(!dst.join("art.zsar").exists(),
            "a failed install must not commit a manifest");
    // load through the same manifest also refuses
    let msg = format!("{}", load(&manifest).expect_err("load must fail"));
    assert!(msg.contains(&label), "load error must name `{label}`: {msg}");

    std::fs::remove_dir_all(&root).ok();
    std::fs::remove_dir_all(&dst).ok();
}

#[test]
fn resumed_install_bit_matches_a_clean_one() {
    let (root, manifest) = packed("resume");
    let m = read_manifest_file(&manifest).expect("manifest");
    let src = ChunkStore::open(&root).expect("src");

    // clean reference install
    let clean_dst = tmp_root("resume_clean");
    let clean_manifest = install(&manifest, &clean_dst, "art")
        .expect("clean install");

    // simulate an install that died partway: copy roughly half the chunks
    // (verified bytes) into the destination, then run the real install
    let resumed_dst = tmp_root("resume_partial");
    let partial = ChunkStore::open(&resumed_dst).expect("partial dst");
    for rec in m.records.iter().step_by(2) {
        let bytes = src.get_verified(rec).expect("src chunk");
        partial.put(&bytes).expect("pre-copy");
    }
    assert!(!resumed_dst.join("art.zsar").exists(),
            "the interrupted install must not have committed");
    let resumed_manifest = install(&manifest, &resumed_dst, "art")
        .expect("resumed install");

    // byte-identical outcome: same manifest bytes, same chunk set
    assert_eq!(std::fs::read(&clean_manifest).expect("clean manifest bytes"),
               std::fs::read(&resumed_manifest).expect("resumed bytes"),
               "resumed install must commit the identical manifest");
    for rec in &m.records {
        let clean_store = ChunkStore::open(&clean_dst).expect("clean store");
        let a = std::fs::read(clean_store.chunk_path(&rec.id))
            .expect("clean chunk");
        let b = std::fs::read(partial.chunk_path(&rec.id))
            .expect("resumed chunk");
        assert_eq!(a, b, "chunk `{}` differs after resume", rec.label);
    }
    // and the installed artifact loads + bit-matches the source
    let src_bundle = load(&manifest).expect("source loads");
    let dst_bundle = load(&resumed_manifest).expect("resumed loads");
    for n in src_bundle.params.names() {
        assert_eq!(src_bundle.params.get(n), dst_bundle.params.get(n),
                   "param {n}");
    }

    std::fs::remove_dir_all(&root).ok();
    std::fs::remove_dir_all(&clean_dst).ok();
    std::fs::remove_dir_all(&resumed_dst).ok();
}
