//! Property-based tests over the linalg substrate, the coordinator
//! invariants (zero-sum selection, budget accounting, plans, quantization,
//! JSON/checkpoint round-trips), and the `ZSAR` artifact manifest / chunk
//! store parsers, using the in-repo `prop::forall` driver.

use zs_svd::artifact::manifest::{MAGIC, VERSION};
use zs_svd::artifact::{ArtifactManifest, ChunkClass, ChunkId, ChunkRecord,
                       ChunkStore};
use zs_svd::compress::selection::{k_threshold, select, Costing, Strategy};
use zs_svd::compress::whiten::{decompose_target, factorize, recompose};
use zs_svd::linalg::{cholesky, cholesky_ridge, effective_rank, gram, matmul,
                     matmul_bt, reconstruct, solve_lower, solve_lower_t, svd};
use zs_svd::linalg::qr::qr;
use zs_svd::model::quant::{int8_error_bound, quant_dequant_int8};
use zs_svd::tensor::Mat;
use zs_svd::util::json;
use zs_svd::util::prop::forall;
use zs_svd::util::rng::Rng;

const CASES: usize = 24;

fn rand_mat(rng: &mut Rng, max_dim: usize) -> Mat {
    let m = rng.range(1, max_dim + 1);
    let n = rng.range(1, max_dim + 1);
    Mat::randn(rng, m, n, 1.0)
}

fn close(a: f64, b: f64, tol: f64) -> bool {
    (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()))
}

// ---------------------------------------------------------------------------
// linalg
// ---------------------------------------------------------------------------

#[test]
fn svd_reconstruction_and_orthogonality() {
    forall("svd-reconstruct", CASES, |rng| rand_mat(rng, 40), |a| {
        let s = svd(a);
        let r = a.rows.min(a.cols);
        let rec = reconstruct(&s, r);
        let err = a.sub(&rec).frob_norm();
        if err > 1e-3 * (1.0 + a.frob_norm()) {
            return Err(format!("reconstruction error {err}"));
        }
        for i in 0..r {
            for j in i..r {
                let mut d = 0.0f64;
                for row in 0..s.u.rows {
                    d += s.u.data[row * s.u.cols + i] as f64
                        * s.u.data[row * s.u.cols + j] as f64;
                }
                let want = if i == j { 1.0 } else { 0.0 };
                if (d - want).abs() > 1e-3 {
                    return Err(format!("U not orthonormal at ({i},{j}): {d}"));
                }
            }
        }
        for w in s.sigma.windows(2) {
            if w[0] < w[1] - 1e-6 || w[1] < -1e-6 {
                return Err(format!("sigma not sorted: {:?}", s.sigma));
            }
        }
        Ok(())
    });
}

#[test]
fn svd_truncation_energy_identity() {
    forall("eckart-young", CASES, |rng| rand_mat(rng, 24), |a| {
        let s = svd(a);
        let r = s.sigma.len();
        let k = r / 2;
        let err2 = a.sub(&reconstruct(&s, k)).frob_norm().powi(2);
        let tail: f64 = s.sigma[k..].iter().map(|&x| (x as f64).powi(2)).sum();
        if tail > 1e-9 && !close(err2, tail, 2e-2) {
            return Err(format!("err² {err2} vs tail {tail}"));
        }
        Ok(())
    });
}

#[test]
fn cholesky_roundtrip_and_solves() {
    forall("cholesky", CASES, |rng| {
        let n = rng.range(1, 32);
        let a = Mat::randn(rng, n + 4, n, 1.0);
        let mut c = gram(&a);
        c.add_diag(0.05);
        let k = rng.range(1, 6);
        let b = Mat::randn(rng, n, k, 1.0);
        (c, b)
    }, |(c, b)| {
        let l = cholesky(c).map_err(|i| format!("not PD at {i}"))?;
        let rec = matmul_bt(&l, &l);
        if rec.sub(c).frob_norm() > 1e-2 * (1.0 + c.frob_norm()) {
            return Err("LLᵀ != C".into());
        }
        let x = solve_lower(&l, b);
        if matmul(&l, &x).sub(b).frob_norm() > 1e-2 * (1.0 + b.frob_norm()) {
            return Err("forward solve failed".into());
        }
        let y = solve_lower_t(&l, b);
        if matmul(&l.transpose(), &y).sub(b).frob_norm()
            > 1e-2 * (1.0 + b.frob_norm())
        {
            return Err("backward solve failed".into());
        }
        Ok(())
    });
}

#[test]
fn qr_orthogonality() {
    forall("qr", CASES, |rng| {
        let n = rng.range(1, 24);
        let m = n + rng.below(16);
        Mat::randn(rng, m, n, 1.0)
    }, |a| {
        let (q, r) = qr(a);
        if matmul(&q, &r).sub(a).frob_norm() > 1e-3 * (1.0 + a.frob_norm()) {
            return Err("QR != A".into());
        }
        let g = matmul(&q.transpose(), &q);
        if g.sub(&Mat::eye(a.cols)).frob_norm() > 1e-3 * a.cols as f64 {
            return Err("QᵀQ != I".into());
        }
        Ok(())
    });
}

#[test]
fn effective_rank_monotone_in_tau() {
    forall("eff-rank", CASES, |rng| {
        let n = rng.range(1, 30);
        (0..n).map(|_| rng.uniform_f32() + 1e-3).collect::<Vec<f32>>()
    }, |sigma| {
        let mut s = sigma.clone();
        s.sort_by(|a, b| b.total_cmp(a));
        let k50 = effective_rank(&s, 0.5);
        let k95 = effective_rank(&s, 0.95);
        let k100 = effective_rank(&s, 1.0);
        if !(k50 <= k95 && k95 <= k100 && k100 <= s.len() && k50 >= 1) {
            return Err(format!("not monotone: {k50} {k95} {k100}"));
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// compression / coordinator invariants
// ---------------------------------------------------------------------------

fn rand_decomps(rng: &mut Rng, count: usize)
                -> Vec<zs_svd::compress::whiten::TargetDecomp> {
    (0..count)
        .map(|i| {
            let m = rng.range(6, 28);
            let n = rng.range(6, 28);
            let w = Mat::randn(rng, m, n, 0.5);
            let x = Mat::randn(rng, 3 * n, n, 1.0);
            let c = gram(&x);
            let g = Mat::randn(rng, m, n, 0.05);
            decompose_target(&format!("t{i}"), &w, &c, &g)
        })
        .collect()
}

#[test]
fn selection_budget_and_order_invariants() {
    forall("selection", CASES, |rng| {
        let count = rng.range(2, 6);
        let ds = rand_decomps(rng, count);
        let ratio = 0.2 + 0.6 * rng.uniform();
        (ds, ratio)
    }, |(ds, ratio)| {
        for costing in [Costing::Standard, Costing::Remap] {
            let r = select(ds, *ratio, costing, Strategy::ZeroSum);
            let total: f64 = ds.iter().map(|d| (d.m * d.n) as f64).sum();
            let budget = (1.0 - ratio) * total;
            let maxcost = ds.iter().map(|d| d.m + d.n).max().unwrap() as f64;
            let drained = ds.iter().all(|d| r.kept[&d.name].len() <= 1);
            if r.saved_params < budget && !drained {
                return Err(format!("{costing:?}: saved {} < {budget}",
                                   r.saved_params));
            }
            if r.saved_params > budget + maxcost {
                return Err("budget overshoot beyond one step".into());
            }
            for d in ds {
                let kept = &r.kept[&d.name];
                if kept.is_empty() {
                    return Err(format!("{} drained to rank 0", d.name));
                }
                for (i, &c) in kept.iter().enumerate() {
                    if c != i {
                        return Err(format!("{} kept not a prefix", d.name));
                    }
                }
                if costing == Costing::Standard {
                    let dense = r.keep_dense[&d.name];
                    let above = kept.len() > k_threshold(d.m, d.n);
                    if dense != above {
                        return Err("keep_dense inconsistent with k_thr".into());
                    }
                }
            }
            let max_dl = ds.iter().flat_map(|d| d.dl.iter())
                .fold(0.0f32, |m, &v| m.max(v.abs())) as f64;
            let bound = (2.0 + r.forced_pops as f64) * max_dl + 1e-9;
            if r.max_abs_s > bound {
                return Err(format!("drift {} > bound {bound}                                     ({} forced pops)", r.max_abs_s, r.forced_pops));
            }
        }
        Ok(())
    });
}

#[test]
fn factorize_recompose_consistency() {
    forall("factorize", CASES, |rng| {
        let ds = rand_decomps(rng, 1);
        let d = ds.into_iter().next().unwrap();
        let r = d.svd.sigma.len();
        let k = rng.range(1, r + 1);
        (d, k)
    }, |(d, k)| {
        let kept: Vec<usize> = (0..*k).collect();
        let (wu, wv) = factorize(d, &kept);
        let rec = recompose(d, &kept);
        let err = matmul(&wu, &wv).sub(&rec).frob_norm();
        if err > 1e-3 * (1.0 + rec.frob_norm()) {
            return Err(format!("factor/recompose mismatch {err}"));
        }
        Ok(())
    });
}

#[test]
fn quantization_error_bounded() {
    forall("int8", CASES, |rng| rand_mat(rng, 32), |w| {
        let q = quant_dequant_int8(w);
        for r in 0..w.rows {
            let maxabs = w.row(r).iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            let bound = int8_error_bound(maxabs) * 1.01;
            for (a, b) in w.row(r).iter().zip(q.row(r)) {
                if (a - b).abs() > bound {
                    return Err(format!("quant error {} > {bound}", (a - b).abs()));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn json_roundtrip_random_values() {
    forall("json", 48, |rng| random_json(rng, 0), |j| {
        let text = j.to_string();
        let back = json::parse(&text)?;
        if &back != j {
            return Err(format!("roundtrip mismatch: {text}"));
        }
        let pretty = j.to_string_pretty();
        let back2 = json::parse(&pretty)?;
        if &back2 != j {
            return Err("pretty roundtrip mismatch".into());
        }
        Ok(())
    });
}

fn random_json(rng: &mut Rng, depth: usize) -> json::Json {
    use json::Json;
    let pick = if depth >= 3 { rng.below(4) } else { rng.below(6) };
    match pick {
        0 => Json::Null,
        1 => Json::Bool(rng.below(2) == 1),
        2 => Json::Num((rng.normal() * 100.0 * 8.0).round() / 8.0),
        3 => {
            let n = rng.below(8);
            Json::Str((0..n).map(|_| {
                let opts = ['a', 'Z', '"', '\\', '\n', '\t', ' ', '\u{e9}'];
                opts[rng.below(opts.len())]
            }).collect())
        }
        4 => Json::Arr((0..rng.below(4))
            .map(|_| random_json(rng, depth + 1)).collect()),
        _ => {
            let n = rng.below(4);
            let mut m = std::collections::BTreeMap::new();
            for i in 0..n {
                m.insert(format!("k{i}"), random_json(rng, depth + 1));
            }
            Json::Obj(m)
        }
    }
}

#[test]
fn checkpoint_roundtrip_random_stores() {
    forall("ckpt", 16, |rng| {
        let n = rng.range(1, 5);
        let names: Vec<String> = (0..n).map(|i| format!("p{i}")).collect();
        let mut store = zs_svd::model::ParamStore::new_empty(names.clone());
        for nm in &names {
            let dims = rng.range(0, 3);
            let shape: Vec<usize> = (0..dims).map(|_| rng.range(1, 7)).collect();
            let mut t = zs_svd::tensor::Tensor::zeros(&shape);
            rng.fill_normal(&mut t.data, 0.0, 1.0);
            store.set(nm, t);
        }
        store
    }, |store| {
        let path = std::env::temp_dir().join(format!(
            "zs_prop_ckpt_{}.zst0", std::process::id()));
        store.save(&path).map_err(|e| e.to_string())?;
        let loaded = zs_svd::model::ParamStore::load(&path)
            .map_err(|e| e.to_string())?;
        std::fs::remove_file(&path).ok();
        if loaded.names() != store.names() {
            return Err("names differ".into());
        }
        for n in store.names() {
            if loaded.get(n) != store.get(n) {
                return Err(format!("tensor {n} differs"));
            }
        }
        Ok(())
    });
}

#[test]
fn zero_sum_keeps_cumulative_loss_change_balanced() {
    // The zero-sum invariant (paper Eq. 11): with Strategy::ZeroSum the
    // running sum of predicted loss changes stays within the sign-balance
    // bound of zero — one max-|ΔL| step of drift, plus one more per pop
    // where the preferred-sign heap was empty — and each matrix's kept set
    // is a σ-descending prefix: a component is never retained while a
    // higher-scoring (larger-σ) component of the same matrix was dropped.
    forall("zero-sum-invariant", CASES, |rng| {
        let count = rng.range(3, 7);
        let ds = rand_decomps(rng, count);
        let ratio = 0.15 + 0.7 * rng.uniform();
        (ds, ratio)
    }, |(ds, ratio)| {
        let r = select(ds, *ratio, Costing::Standard, Strategy::ZeroSum);
        let max_dl = ds
            .iter()
            .flat_map(|d| d.dl.iter())
            .fold(0.0f32, |m, &v| m.max(v.abs())) as f64;
        let bound = (2.0 + r.forced_pops as f64) * max_dl + 1e-9;
        if r.final_s.abs() > bound {
            return Err(format!("final drift {} exceeds bound {bound}", r.final_s));
        }
        if r.max_abs_s > bound {
            return Err(format!("peak drift {} exceeds bound {bound}", r.max_abs_s));
        }
        for d in ds {
            let kept = &r.kept[&d.name];
            if kept.is_empty() {
                return Err(format!("{} drained to rank 0", d.name));
            }
            for (i, &c) in kept.iter().enumerate() {
                if c != i {
                    return Err(format!(
                        "{}: kept {:?} retains component {c} while a \
                         higher-σ one was dropped", d.name, kept));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn zero_sum_removal_monotone_in_budget() {
    // Shrinking the retention ratio (growing the removal budget) can only
    // remove more components, never fewer.
    forall("zero-sum-monotone", CASES, |rng| {
        let ds = rand_decomps(rng, rng.range(2, 5));
        let hi = 0.5 + 0.4 * rng.uniform();
        let lo = hi - 0.3;
        (ds, lo, hi)
    }, |(ds, lo, hi)| {
        let aggressive = select(ds, *lo, Costing::Standard, Strategy::ZeroSum);
        let mild = select(ds, *hi, Costing::Standard, Strategy::ZeroSum);
        if aggressive.removed < mild.removed {
            return Err(format!(
                "removed {} at ratio {lo} but {} at ratio {hi}",
                aggressive.removed, mild.removed));
        }
        if aggressive.saved_params + 1e-9 < mild.saved_params {
            return Err("saved_params not monotone in budget".into());
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// artifact manifest / chunk store
// ---------------------------------------------------------------------------

fn random_bytes(rng: &mut Rng, max_len: usize) -> Vec<u8> {
    (0..rng.below(max_len)).map(|_| rng.below(256) as u8).collect()
}

fn random_manifest(rng: &mut Rng) -> ArtifactManifest {
    let classes = [ChunkClass::Meta, ChunkClass::Param, ChunkClass::FactorU,
                   ChunkClass::FactorV];
    let n = rng.below(6);
    let records = (0..n)
        .map(|i| {
            let payload = random_bytes(rng, 48);
            // the index prefix keeps labels unique; the tail exercises
            // variable label lengths including empty tails
            let label = format!("c{i}:{}", "x".repeat(rng.below(12)));
            ChunkRecord { class: classes[rng.below(classes.len())], label,
                          id: ChunkId::of(&payload),
                          len: payload.len() as u64 }
        })
        .collect();
    ArtifactManifest { records }
}

#[test]
fn artifact_manifest_roundtrip_byte_identical() {
    forall("zsar-roundtrip", 48, random_manifest, |m| {
        let enc = m.encode();
        let dec = ArtifactManifest::decode(&enc)?;
        if &dec != m {
            return Err("decoded manifest differs from the original".into());
        }
        if dec.encode() != enc {
            return Err("re-encode is not byte-identical".into());
        }
        Ok(())
    });
}

#[test]
fn artifact_manifest_corruption_always_detected() {
    // any single flipped bit and any truncation must fail decoding — the
    // trailing body hash plus the checked header make both unconcealable
    forall("zsar-corrupt", 32, |rng| {
        let enc = random_manifest(rng).encode();
        let pos = rng.below(enc.len());
        let bit = 1u8 << rng.below(8);
        let cut = rng.below(enc.len());
        (enc, pos, bit, cut)
    }, |(enc, pos, bit, cut)| {
        let mut flipped = enc.clone();
        flipped[*pos] ^= *bit;
        if ArtifactManifest::decode(&flipped).is_ok() {
            return Err(format!("bit flip at byte {pos} still decoded"));
        }
        if ArtifactManifest::decode(&enc[..*cut]).is_ok() {
            return Err(format!("truncation to {cut} bytes still decoded"));
        }
        Ok(())
    });
}

#[test]
fn artifact_manifest_hostile_inputs_never_panic() {
    // adversarial inputs: raw garbage, and garbage wearing a plausible
    // header that claims absurd body lengths / record counts.  Decoding
    // must return structured errors — never panic, never allocate on the
    // attacker's say-so.  Anything it does accept must be canonical.
    forall("zsar-hostile", 64, |rng| {
        let mut bytes = random_bytes(rng, 200);
        if rng.below(2) == 1 && bytes.len() >= 16 {
            bytes[..4].copy_from_slice(MAGIC);
            bytes[4..8].copy_from_slice(&VERSION.to_le_bytes());
            if rng.below(2) == 1 {
                // lie enormously about the body size
                let lie = u64::MAX - rng.below(1024) as u64;
                bytes[8..16].copy_from_slice(&lie.to_le_bytes());
            }
        }
        bytes
    }, |bytes| {
        if let Ok(m) = ArtifactManifest::decode(bytes) {
            if m.records.len() > bytes.len() {
                return Err("accepted more records than input bytes".into());
            }
            if m.encode() != *bytes {
                return Err("accepted a non-canonical encoding".into());
            }
        }
        Ok(())
    });
}

#[test]
fn chunk_store_roundtrip_and_corruption_detection() {
    let root = std::env::temp_dir()
        .join(format!("zs_prop_chunks_{}", std::process::id()));
    std::fs::remove_dir_all(&root).ok();
    let store = ChunkStore::open(&root).expect("store opens");
    forall("chunk-store", 24, |rng| random_bytes(rng, 200), |payload| {
        let rec = ChunkRecord { class: ChunkClass::Param,
                                label: "param:prop".into(),
                                id: ChunkId::of(payload),
                                len: payload.len() as u64 };
        let id = store.put(payload).map_err(|e| format!("put: {e}"))?;
        if id != rec.id {
            return Err("put returned a different content id".into());
        }
        if !store.has_valid(&rec) {
            return Err("freshly stored chunk does not verify".into());
        }
        let back = store.get_verified(&rec)
            .map_err(|e| format!("get_verified: {e}"))?;
        if &back != payload {
            return Err("chunk roundtrip differs".into());
        }
        // corrupt the file on disk: verification must fail and the error
        // must name the chunk's label
        let path = store.chunk_path(&rec.id);
        let mut bytes = std::fs::read(&path).map_err(|e| format!("{e}"))?;
        if bytes.is_empty() {
            bytes.push(0);
        } else {
            let mid = bytes.len() / 2;
            bytes[mid] ^= 0x40;
        }
        std::fs::write(&path, &bytes).map_err(|e| format!("{e}"))?;
        if store.has_valid(&rec) {
            return Err("corrupted chunk still reports valid".into());
        }
        let err = match store.get_verified(&rec) {
            Ok(_) => return Err("corrupted chunk still verified".into()),
            Err(e) => format!("{e}"),
        };
        if !err.contains("param:prop") {
            return Err(format!("error must name the chunk label: {err}"));
        }
        // putting the good bytes back heals the store in place
        store.put(payload).map_err(|e| format!("re-put: {e}"))?;
        if !store.has_valid(&rec) {
            return Err("re-put did not restore the chunk".into());
        }
        std::fs::remove_file(&path).ok();
        Ok(())
    });
    std::fs::remove_dir_all(&root).ok();
}

#[test]
fn whitening_ridge_always_succeeds() {
    forall("ridge", CASES, |rng| {
        // possibly rank-deficient moments (fewer samples than dims)
        let n = rng.range(2, 24);
        let t = rng.range(1, n);
        let x = Mat::randn(rng, t, n, 1.0);
        gram(&x)
    }, |c| {
        let (l, lambda) = cholesky_ridge(c, 1e-6);
        if lambda <= 0.0 || !l.is_finite() {
            return Err(format!("ridge failed (lambda {lambda})"));
        }
        Ok(())
    });
}
