//! End-to-end integration over the runtime ABI: every session surface
//! (fwd, b1 dispatch, train, grads, moments, lowrank) gets exercised once.
//! Runs on the native runtime with the built-in manifest; with
//! `make artifacts` the same tests validate a real artifact directory.

use std::collections::BTreeMap;

use zs_svd::data::{default_world, training_corpus};
use zs_svd::linalg::{factor, matmul, svd};
use zs_svd::model::init::{init_params, zero_state};
use zs_svd::runtime::session::Session;
use zs_svd::runtime::Runtime;
use zs_svd::tensor::Mat;
use zs_svd::trainer::{train, TrainConfig};
use zs_svd::util::rng::Rng;

fn runtime() -> Runtime {
    Runtime::load_default().expect("run `make artifacts` first")
}

#[test]
fn fwd_loss_near_uniform_at_init() {
    let rt = runtime();
    let sess = Session::new(&rt, "tiny");
    let mut rng = Rng::new(1);
    let params = init_params(&sess.cfg, &mut rng);
    let world = default_world();
    let corpus = training_corpus("llama", &world);
    let batch = corpus.sample_batch(&mut rng, sess.cfg.batch, sess.cfg.seq_len);
    let (loss, logits) = sess.fwd(&params, &batch).unwrap();
    // fresh init => loss ~ ln(256) = 5.545
    assert!((loss - 5.545).abs() < 0.4, "loss {loss}");
    assert_eq!(logits.shape,
               vec![sess.cfg.batch, sess.cfg.seq_len, sess.cfg.vocab]);
    assert!(logits.data.iter().all(|v| v.is_finite()));
}

#[test]
fn b1_artifact_matches_config() {
    let rt = runtime();
    let sess = Session::new(&rt, "tiny");
    let mut rng = Rng::new(2);
    let params = init_params(&sess.cfg, &mut rng);
    let world = default_world();
    let corpus = training_corpus("llama", &world);
    let batch = corpus.sample_batch(&mut rng, 1, sess.cfg.seq_len);
    let (loss, logits) = sess.fwd(&params, &batch).unwrap();
    assert!(loss.is_finite());
    assert_eq!(logits.shape, vec![1, sess.cfg.seq_len, sess.cfg.vocab]);
}

#[test]
fn train_step_learns() {
    let rt = runtime();
    let sess = Session::new(&rt, "tiny");
    let world = default_world();
    let corpus = training_corpus("llama", &world);
    let tc = TrainConfig { steps: 25, lr: 3e-3, warmup: 5, seed: 3, log_every: 100 };
    let result = train(&sess, &corpus, &tc, true).unwrap();
    let first = result.losses[0];
    let last = *result.losses.last().unwrap();
    assert!(last < first - 0.8,
            "no learning: first {first}, last {last}");
}

#[test]
fn grads_and_moments_consistent() {
    let rt = runtime();
    let sess = Session::new(&rt, "tiny");
    let mut rng = Rng::new(4);
    let params = init_params(&sess.cfg, &mut rng);
    let world = default_world();
    let corpus = training_corpus("llama", &world);
    let b1 = corpus.calibration_batch(&mut rng, sess.cfg.batch, sess.cfg.seq_len);
    let b2 = corpus.calibration_batch(&mut rng, sess.cfg.batch, sess.cfg.seq_len);

    let (loss, grads) = sess.grads(&params, &b1).unwrap();
    assert!(loss.is_finite());
    assert_eq!(grads.len(), sess.cfg.targets.len());
    for (name, g) in &grads {
        let t = sess.cfg.target(name);
        assert_eq!((g.rows, g.cols), t.shape);
        assert!(g.is_finite(), "{name}");
        assert!(g.frob_norm() > 0.0, "{name} grad is zero");
    }

    let moments = sess.accumulate_moments(&params, &[b1, b2]).unwrap();
    assert_eq!(moments.len(), sess.cfg.sites.len());
    for sm in &moments {
        let n = sess.cfg.site_dim(&sm.site);
        assert_eq!((sm.xx.rows, sm.xx.cols), (n, n));
        assert_eq!(sm.count, 2 * sess.cfg.batch * sess.cfg.seq_len);
        for i in 0..n {
            assert!(sm.xx.at(i, i) >= -1e-3);
            for j in 0..n {
                let d = (sm.xx.at(i, j) - sm.xx.at(j, i)).abs();
                assert!(d <= 1e-2 * (1.0 + sm.xx.at(i, j).abs()), "{}", sm.site);
            }
        }
    }
}

#[test]
fn lowrank_fullrank_factorization_matches_dense() {
    // Factor every target at the artifact's uniform rank via SVD of the true
    // weight; the pallas low-rank forward must match the *rank-truncated
    // dense recomposition* run through the dense artifact.
    let rt = runtime();
    let sess = Session::new(&rt, "tiny");
    let mut rng = Rng::new(5);
    let params = init_params(&sess.cfg, &mut rng);
    let world = default_world();
    let corpus = training_corpus("llama", &world);
    let batch = corpus.sample_batch(&mut rng, sess.cfg.batch, sess.cfg.seq_len);

    let tag = "80";
    let lm = sess.cfg.lowrank.get(tag).unwrap().clone();
    let mut factors: BTreeMap<String, (Mat, Mat)> = BTreeMap::new();
    let mut dense = params.clone();
    for t in &sess.cfg.targets {
        let w = params.get(&t.name).to_mat();
        let s = svd(&w);
        let k = lm.ranks[&t.name];
        let (wu, wv) = factor(&s, k);
        let rec = matmul(&wu, &wv);
        dense.set(&t.name, zs_svd::tensor::Tensor::from_mat(&rec));
        factors.insert(t.name.clone(), (wu, wv));
    }

    let (loss_dense, logits_dense) = sess.fwd(&dense, &batch).unwrap();
    let (loss_lr, logits_lr) = sess.lowrank_fwd(tag, &params, &factors, &batch).unwrap();
    assert!((loss_dense - loss_lr).abs() < 5e-3,
            "dense {loss_dense} vs lowrank {loss_lr}");
    let max_dev = logits_dense
        .data
        .iter()
        .zip(&logits_lr.data)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_dev < 0.05, "max logit deviation {max_dev}");
}

#[test]
fn adam_state_zero_init_matches_spec() {
    let rt = runtime();
    let sess = Session::new(&rt, "tiny");
    let z = zero_state(&sess.cfg);
    assert_eq!(z.len(), sess.cfg.params.len());
    assert!(z.ordered().iter().all(|t| t.data.iter().all(|&v| v == 0.0)));
}
