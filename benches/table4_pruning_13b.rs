//! Table 4 — ZS-SVD vs pruning on the LLaMA-13B analog (`small`) at
//! retention 0.8.  Task columns: OpenBook(=BoolQ slot) / PIQA / WinoGrande /
//! ARC-E / ARC-C analogs.

mod common;

use zs_svd::compress::baselines::PruneScore;
use zs_svd::coordinator::{self, Method};
use zs_svd::data::TaskFamily;
use zs_svd::eval;
use zs_svd::report::{acc2, Table};
use zs_svd::util::benchkit::fast_mode;

const FAMS: [TaskFamily; 5] = [TaskFamily::OpenbSyn, TaskFamily::PiqaSyn,
                               TaskFamily::WinogSyn, TaskFamily::ArcESyn,
                               TaskFamily::ArcCSyn];

fn main() {
    let rt = common::runtime();
    let p = common::prepare(rt, "small", "llama", 7);
    let spec = common::spec();
    let ratio = 0.35; // paper band 0.8

    let eval_subset = |params: &zs_svd::model::ParamStore| {
        eval::evaluate_subset(&p.session, params, &p.eval_corpora, &p.world,
                              &spec, &FAMS).unwrap()
    };
    let base = eval_subset(&p.params);

    let mut t = Table::new(
        "Table 4: vs pruning on the 13B analog (small) at 0.8",
        &["method", "openb", "piqa", "winog", "arc_e", "arc_c", "avg"],
    );
    let push = |label: &str, r: &eval::EvalReport, t: &mut Table| {
        let mut row = vec![label.to_string()];
        for (_, a) in &r.acc {
            row.push(acc2(*a));
        }
        row.push(acc2(r.avg_acc()));
        t.row(row);
    };
    push("baseline", &base, &mut t);

    let mut methods = vec![
        Method::Prune(PruneScore::Magnitude),
        Method::Prune(PruneScore::Flap),
        Method::SvdLlm,
        Method::zs(ratio),
        Method::DobiSimRemap { sweeps: 1 },
        Method::zs_remap(ratio),
    ];
    if fast_mode() {
        methods.truncate(3);
    }
    for m in methods {
        let plan = coordinator::run_method(&p, &m, ratio).unwrap();
        let r = eval_subset(&plan.apply(&p.params));
        eprintln!("  {}: done", plan.method);
        push(&plan.method, &r, &mut t);
    }

    common::emit("table4_pruning_13b", &t);
}
