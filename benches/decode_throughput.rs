//! Decode-phase throughput — dense vs low-rank token generation under the
//! continuous-batching scheduler (the decode-side companion of Table 7,
//! matching SVD-LLM's decode tokens/sec efficiency metric).
//!
//! Every engine serves the SAME synthetic request stream (random prompts,
//! greedy sampling, saturating arrivals) through the KV-cached batched
//! step kernel: the dense baseline against ZS-SVD low-rank factors at two
//! compression ratios, capped/padded onto the fixed-rank artifacts exactly
//! as in the prefill benchmark.  Prefill and decode phases are reported as
//! separate token rates (`common::PHASE_HEADERS`): prefill runs through
//! the chunked batched-GEMM ingest, decode through the across-slot batched
//! step, and folding them into one number would hide both effects.
//!
//! The second half sweeps **speculative self-decode**: the dense target
//! speculating through a high-compression ZS-SVD drafter at K ∈ {2, 4}
//! against the K = 0 baseline.  Greedy tokens are bit-identical at every K
//! (`rust/tests/decode_parity.rs` gates that), so the sweep isolates the
//! rate effect — acceptance rate and the decode tok/s ratio vs K = 0 — and
//! records it machine-readably in `BENCH_6.json` at the repo root.
//!
//! The whole harness runs with the observability layer on
//! (`zs_svd::obs`): tracing is observe-only (`rust/tests/trace_equiv.rs`
//! gates bit-identity), so the scheduler's per-phase counters can be read
//! after every run for free.  The resulting prefill / decode / draft /
//! verify wall-time breakdown per engine lands in `BENCH_7.json`.

mod common;

use zs_svd::coordinator::{self, Method};
use zs_svd::decode::{run_decode, run_decode_speculative, synth_requests,
                     DecodeConfig};
use zs_svd::report::{f2, latency_cells, mb, Table, LATENCY_HEADERS};
use zs_svd::serve::Engine;
use zs_svd::util::benchkit::fast_mode;
use zs_svd::util::json::Json;

fn main() {
    let rt = common::runtime();
    let p = common::prepare(rt, "tiny", "llama", 7);
    // per-phase wall-time attribution via the observe-only tracing layer;
    // reset before each measured run so every counter read is one run's
    zs_svd::obs::set_enabled(true);
    let mut phase_rows: Vec<Json> = Vec::new();
    let (n_requests, max_new) = if fast_mode() { (6, 8) } else { (24, 32) };
    let prompt_len = p.session.cfg.seq_len / 4;

    let dc = DecodeConfig {
        max_slots: 4,
        max_new_tokens: max_new,
        temperature: 0.0,
        seed: 1,
        arrival_steps: 0.0, // saturating queue
        prefill_chunk: 0,   // whole-prompt chunks: peak prefill batching
        speculate_k: 0,
        ..DecodeConfig::default()
    };
    let reqs = synth_requests(&p.session.cfg, n_requests, prompt_len, max_new,
                              0xD0);

    let mut headers = vec!["engine", "compression"];
    headers.extend(common::PHASE_HEADERS);
    headers.push("total tok/s");
    headers.extend(LATENCY_HEADERS);
    headers.extend(["ttft p50 ms", "KV MB/slot"]);
    let mut t = Table::new(
        "decode throughput (KV-cached generation, continuous batching)",
        &headers,
    );

    zs_svd::obs::reset();
    let (d, _) = run_decode(&p.session, &p.params, &Engine::Dense, &reqs, &dc)
        .expect("dense decode");
    phase_rows.push(common::phase_row(&d.engine, 0, d.decode_tok_per_sec));
    eprintln!("  dense: {:.0} prefill tok/s, {:.0} decode tok/s",
              d.prefill_tok_per_sec, d.decode_tok_per_sec);
    let mut row = vec!["original".into(), "0%".into()];
    row.extend(common::phase_cells(d.prefill_tok_per_sec,
                                   d.decode_tok_per_sec));
    row.push(f2(d.total_tok_per_sec));
    row.extend(latency_cells(&d.latency));
    row.extend([f2(d.ttft.p50), mb(d.kv_bytes_per_slot as f64)]);
    t.row(row);

    for (comp, ratio) in [("40%", 0.6), ("60%", 0.4)] {
        let plan = coordinator::run_method(&p, &Method::zs(ratio), ratio)
            .expect("compress");
        let tag = format!("{}", (ratio * 100.0) as usize);
        let lm = p.session.cfg.lowrank.get(&tag).expect("artifact tag");
        let engine = Engine::from_plan_capped(&tag, &plan, &lm.ranks);
        let params = plan.apply(&p.params);
        zs_svd::obs::reset();
        let (s, _) = run_decode(&p.session, &params, &engine, &reqs, &dc)
            .expect("lowrank decode");
        phase_rows.push(common::phase_row(&s.engine, 0,
                                          s.decode_tok_per_sec));
        eprintln!("  {}@{comp}: {:.0} prefill tok/s, {:.0} decode tok/s",
                  plan.method, s.prefill_tok_per_sec, s.decode_tok_per_sec);
        let mut row = vec![plan.method.clone(), comp.into()];
        row.extend(common::phase_cells(s.prefill_tok_per_sec,
                                       s.decode_tok_per_sec));
        row.push(f2(s.total_tok_per_sec));
        row.extend(latency_cells(&s.latency));
        row.extend([f2(s.ttft.p50), mb(s.kv_bytes_per_slot as f64)]);
        t.row(row);
    }

    // ---------------------------------------------------------------
    // speculative self-decode: dense target + ZS-SVD drafter (ratio 0.4,
    // the same 60%-compression artifact the serve CLI's default
    // `--draft-ratio 0.4` selects).  K = 0 is the dense baseline already
    // measured above; tokens are bit-identical at every K, so the only
    // things that move are the acceptance rate and the decode tok/s.
    // ---------------------------------------------------------------
    let dratio = 0.4;
    let dtag = format!("{}", (dratio * 100.0) as usize);
    let dplan = coordinator::run_method(&p, &Method::zs(dratio), dratio)
        .expect("compress drafter");
    let dlm = p.session.cfg.lowrank.get(&dtag).expect("artifact tag");
    let drafter = Engine::from_plan_capped(&dtag, &dplan, &dlm.ranks);

    let base_decode = d.decode_tok_per_sec;
    let mut spec_results = vec![Json::obj(vec![
        ("speculate_k", Json::num(0.0)),
        ("engine", Json::str(&d.engine)),
        ("decode_tok_per_sec", Json::num(d.decode_tok_per_sec)),
        ("prefill_tok_per_sec", Json::num(d.prefill_tok_per_sec)),
        ("decode_speedup_vs_k0", Json::num(1.0)),
        ("drafted_tokens", Json::num(0.0)),
        ("accepted_draft_tokens", Json::num(0.0)),
        ("acceptance_rate", Json::num(0.0)),
    ])];
    for k in [2usize, 4] {
        let dc_k = DecodeConfig { speculate_k: k, ..dc.clone() };
        zs_svd::obs::reset();
        let (s, _) = run_decode_speculative(&p.session, &p.params,
                                            &Engine::Dense, &drafter, &reqs,
                                            &dc_k)
            .expect("speculative decode");
        phase_rows.push(common::phase_row(&s.engine, k,
                                          s.decode_tok_per_sec));
        let speedup = if base_decode > 0.0 {
            s.decode_tok_per_sec / base_decode
        } else {
            0.0
        };
        eprintln!("  {}: {:.0} decode tok/s ({speedup:.2}x vs K=0), \
                   acceptance {:.2} ({}/{} drafted)",
                  s.engine, s.decode_tok_per_sec, s.draft_acceptance,
                  s.accepted_draft_tokens, s.drafted_tokens);
        let mut row = vec![s.engine.clone(), "0%".into()];
        row.extend(common::phase_cells(s.prefill_tok_per_sec,
                                       s.decode_tok_per_sec));
        row.push(f2(s.total_tok_per_sec));
        row.extend(latency_cells(&s.latency));
        row.extend([f2(s.ttft.p50), mb(s.kv_bytes_per_slot as f64)]);
        t.row(row);
        spec_results.push(Json::obj(vec![
            ("speculate_k", Json::num(k as f64)),
            ("engine", Json::str(&s.engine)),
            ("decode_tok_per_sec", Json::num(s.decode_tok_per_sec)),
            ("prefill_tok_per_sec", Json::num(s.prefill_tok_per_sec)),
            ("decode_speedup_vs_k0", Json::num(speedup)),
            ("drafted_tokens", Json::num(s.drafted_tokens as f64)),
            ("accepted_draft_tokens",
             Json::num(s.accepted_draft_tokens as f64)),
            ("acceptance_rate", Json::num(s.draft_acceptance)),
        ]));
    }

    let bench6 = Json::obj(vec![
        ("bench", Json::str("decode_throughput/speculative")),
        ("generated_by",
         Json::str("cargo bench --bench decode_throughput (also run by ci.sh)")),
        ("fast_mode", Json::Bool(fast_mode())),
        ("target", Json::str(&d.engine)),
        ("drafter", Json::str(&format!("lowrank-r{dtag} (ratio {dratio})"))),
        ("units", Json::str("decode_tok_per_sec over batched decode-step \
                             wall time; speedup is the ratio to the K=0 \
                             dense baseline; greedy tokens bit-identical \
                             at every K")),
        ("results", Json::Arr(spec_results)),
    ]);
    let bench6_path = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("BENCH_6.json");
    std::fs::write(&bench6_path, bench6.to_string_pretty() + "\n")
        .expect("write BENCH_6.json");
    println!("[saved {}]", bench6_path.display());

    // ---------------------------------------------------------------
    // per-phase wall-time breakdown (BENCH_7): what each engine's
    // scheduler time went to — prefill ingest, decode steps, and (for the
    // speculative rows) draft proposal vs batched verification.  Read
    // straight from the obs phase counters the traced runs accumulated.
    // ---------------------------------------------------------------
    let mut pt = Table::new(
        "scheduler phase breakdown (wall ms, from obs counters)",
        &["engine", "K", "prefill ms", "decode ms", "draft ms",
          "verify ms"],
    );
    for r in &phase_rows {
        pt.row(vec![
            r.str_or("engine", "?"),
            format!("{}", r.usize_or("speculate_k", 0)),
            f2(r.f64_or("prefill_ms", 0.0)),
            f2(r.f64_or("decode_ms", 0.0)),
            f2(r.f64_or("draft_ms", 0.0)),
            f2(r.f64_or("verify_ms", 0.0)),
        ]);
    }
    common::emit("decode_phase_breakdown", &pt);

    let bench7 = Json::obj(vec![
        ("bench", Json::str("decode_throughput/phase_breakdown")),
        ("generated_by",
         Json::str("cargo bench --bench decode_throughput (also run by ci.sh)")),
        ("fast_mode", Json::Bool(fast_mode())),
        ("units", Json::str("wall milliseconds per scheduler phase, summed \
                             over one run's iterations, read from the \
                             observability layer's phase.* counters; \
                             tracing is observe-only, so the measured runs \
                             are bit-identical to untraced ones")),
        ("results", Json::Arr(phase_rows)),
    ]);
    let bench7_path = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("BENCH_7.json");
    std::fs::write(&bench7_path, bench7.to_string_pretty() + "\n")
        .expect("write BENCH_7.json");
    println!("[saved {}]", bench7_path.display());

    common::emit("decode_throughput", &t);
}
