//! Decode-phase throughput — dense vs low-rank token generation under the
//! continuous-batching scheduler (the decode-side companion of Table 7,
//! matching SVD-LLM's decode tokens/sec efficiency metric).
//!
//! Every engine serves the SAME synthetic request stream (random prompts,
//! greedy sampling, saturating arrivals) through the KV-cached batched
//! step kernel: the dense baseline against ZS-SVD low-rank factors at two
//! compression ratios, capped/padded onto the fixed-rank artifacts exactly
//! as in the prefill benchmark.  Prefill and decode phases are reported as
//! separate token rates (`common::PHASE_HEADERS`): prefill runs through
//! the chunked batched-GEMM ingest, decode through the across-slot batched
//! step, and folding them into one number would hide both effects.

mod common;

use zs_svd::coordinator::{self, Method};
use zs_svd::decode::{run_decode, synth_requests, DecodeConfig};
use zs_svd::report::{f2, latency_cells, mb, Table, LATENCY_HEADERS};
use zs_svd::serve::Engine;
use zs_svd::util::benchkit::fast_mode;

fn main() {
    let rt = common::runtime();
    let p = common::prepare(rt, "tiny", "llama", 7);
    let (n_requests, max_new) = if fast_mode() { (6, 8) } else { (24, 32) };
    let prompt_len = p.session.cfg.seq_len / 4;

    let dc = DecodeConfig {
        max_slots: 4,
        max_new_tokens: max_new,
        temperature: 0.0,
        seed: 1,
        arrival_steps: 0.0, // saturating queue
        prefill_chunk: 0,   // whole-prompt chunks: peak prefill batching
    };
    let reqs = synth_requests(&p.session.cfg, n_requests, prompt_len, max_new,
                              0xD0);

    let mut headers = vec!["engine", "compression"];
    headers.extend(common::PHASE_HEADERS);
    headers.push("total tok/s");
    headers.extend(LATENCY_HEADERS);
    headers.extend(["ttft p50 ms", "KV MB/slot"]);
    let mut t = Table::new(
        "decode throughput (KV-cached generation, continuous batching)",
        &headers,
    );

    let (d, _) = run_decode(&p.session, &p.params, &Engine::Dense, &reqs, &dc)
        .expect("dense decode");
    eprintln!("  dense: {:.0} prefill tok/s, {:.0} decode tok/s",
              d.prefill_tok_per_sec, d.decode_tok_per_sec);
    let mut row = vec!["original".into(), "0%".into()];
    row.extend(common::phase_cells(d.prefill_tok_per_sec,
                                   d.decode_tok_per_sec));
    row.push(f2(d.total_tok_per_sec));
    row.extend(latency_cells(&d.latency));
    row.extend([f2(d.ttft.p50), mb(d.kv_bytes_per_slot as f64)]);
    t.row(row);

    for (comp, ratio) in [("40%", 0.6), ("60%", 0.4)] {
        let plan = coordinator::run_method(&p, &Method::zs(ratio), ratio)
            .expect("compress");
        let tag = format!("{}", (ratio * 100.0) as usize);
        let lm = p.session.cfg.lowrank.get(&tag).expect("artifact tag");
        let engine = Engine::from_plan_capped(&tag, &plan, &lm.ranks);
        let params = plan.apply(&p.params);
        let (s, _) = run_decode(&p.session, &params, &engine, &reqs, &dc)
            .expect("lowrank decode");
        eprintln!("  {}@{comp}: {:.0} prefill tok/s, {:.0} decode tok/s",
                  plan.method, s.prefill_tok_per_sec, s.decode_tok_per_sec);
        let mut row = vec![plan.method.clone(), comp.into()];
        row.extend(common::phase_cells(s.prefill_tok_per_sec,
                                       s.decode_tok_per_sec));
        row.push(f2(s.total_tok_per_sec));
        row.extend(latency_cells(&s.latency));
        row.extend([f2(s.ttft.p50), mb(s.kv_bytes_per_slot as f64)]);
        t.row(row);
    }

    common::emit("decode_throughput", &t);
}
