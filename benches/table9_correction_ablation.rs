//! Table 9 (Appendix B.1) — correction-variant ablation at retention 0.4:
//! α-blend {0.25, 0.5, 0.75}, plain GD steps {1e-2, 1e-3, 1e-4},
//! Proj-Δ, and the paper's Proj-Grad — each applied once after the first
//! truncation, followed by re-truncation.  WikiText-2 PPL.

mod common;

use zs_svd::compress::CorrectionKind;
use zs_svd::coordinator::{self, Method};
use zs_svd::report::{f2, Table};

fn main() {
    let rt = common::runtime();
    let p = common::prepare(rt, "tiny", "llama", 7);
    let spec = common::spec();
    let ratio = 0.15; // paper band 0.4

    let variants: Vec<CorrectionKind> = vec![
        CorrectionKind::AlphaBlend(0.25),
        CorrectionKind::AlphaBlend(0.50),
        CorrectionKind::AlphaBlend(0.75),
        CorrectionKind::GradStep(1e-2),
        CorrectionKind::GradStep(1e-3),
        CorrectionKind::GradStep(1e-4),
        CorrectionKind::ProjDelta,
        CorrectionKind::ProjGrad,
    ];

    let mut t = Table::new(
        "Table 9: correction variants at ratio 0.4 (wiki PPL, 1 iteration)",
        &["variant", "ppl(wiki)"],
    );

    // no-correction reference
    let plain = coordinator::run_method(&p, &Method::zs(ratio), ratio).unwrap();
    let r0 = coordinator::evaluate_plan(&p, Some(&plain), &spec).unwrap();
    t.row(vec!["none".into(), f2(r0.ppl_of("wiki-syn"))]);

    for kind in variants {
        let m = Method::zs_correction_kind(ratio, kind);
        let plan = coordinator::run_method(&p, &m, ratio).unwrap();
        let r = coordinator::evaluate_plan(&p, Some(&plan), &spec).unwrap();
        eprintln!("  {}: {:.2}", kind.label(), r.ppl_of("wiki-syn"));
        t.row(vec![kind.label(), f2(r.ppl_of("wiki-syn"))]);
    }

    common::emit("table9_correction_ablation", &t);
}
