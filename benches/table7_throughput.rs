//! Table 7 — serving throughput + memory under compression, two serving
//! regimes standing in for the paper's two GPUs:
//!   "slow"    = batch 1  (Titan-Xp-like memory-constrained regime)
//!   "regular" = batch 8  (A5000-like batched regime)
//! Engines: dense baseline vs SVD-LLM / ZS-SVD low-rank factors through the
//! fused Pallas artifacts at 40% and 60% compression.

mod common;

use zs_svd::coordinator::{self, Method};
use zs_svd::report::{f2, Table};
use zs_svd::serve::{run_serving, Engine, ServeConfig};
use zs_svd::util::benchkit::fast_mode;

fn main() {
    let rt = common::runtime();
    let p = common::prepare(rt, "tiny", "llama", 7);
    let n_requests = if fast_mode() { 16 } else { 48 };

    let mut t = Table::new(
        "Table 7: throughput & memory (dense vs low-rank serving)",
        &["regime", "compression", "method", "tok/s", "p95 ms", "p99 ms",
          "weights MB", "act MB", "peak RSS MB"],
    );

    let dense_bytes = p.session.cfg.param_count() as f64 * 2.0;
    for (regime, max_batch, tag_suffix) in [("regular", 8usize, ""),
                                            ("slow", 1usize, "_b1")] {
        let sc = ServeConfig { n_requests, max_batch, arrival_factor: 0.5,
                               seed: 1, ..ServeConfig::default() };
        let d = run_serving(&p.session, &p.params, &Engine::Dense, &sc,
                            dense_bytes).unwrap();
        t.row(vec![regime.into(), "0%".into(), "original".into(),
                   f2(d.tokens_per_sec), f2(d.latency.p95), f2(d.latency.p99),
                   f2(d.weight_mem_bytes / 1e6),
                   f2(d.act_mem_bytes as f64 / 1e6),
                   f2(d.peak_mem_bytes as f64 / 1e6)]);

        for (comp, ratio) in [("40%", 0.6), ("60%", 0.4)] {
            for m in [Method::SvdLlm, Method::zs(ratio)] {
                let plan = coordinator::run_method(&p, &m, ratio).unwrap();
                let tag = format!("{}{}", (ratio * 100.0) as usize, tag_suffix);
                let lm = p.session.cfg.lowrank.get(&tag).unwrap();
                let engine = Engine::from_plan_capped(&tag, &plan, &lm.ranks);
                let params = plan.apply(&p.params);
                let s = run_serving(&p.session, &params, &engine, &sc,
                                    plan.model_bytes(&p.session.cfg)).unwrap();
                eprintln!("  {regime}/{comp}/{}: {:.0} tok/s",
                          plan.method, s.tokens_per_sec);
                t.row(vec![regime.into(), comp.into(), plan.method.clone(),
                           f2(s.tokens_per_sec), f2(s.latency.p95),
                           f2(s.latency.p99),
                           f2(s.weight_mem_bytes / 1e6),
                           f2(s.act_mem_bytes as f64 / 1e6),
                           f2(s.peak_mem_bytes as f64 / 1e6)]);
            }
        }
    }

    common::emit("table7_throughput", &t);
}
