//! Table 2 — 30% pruning protocol (retention 0.7) on the LLaMA-7B and
//! Vicuna-7B analogs: ASVD / FWSVD / SVD-LLM / ZS-SVD.
//! (DipSVD itself has no public implementation — the paper also ran this
//! table against reported numbers; we run our implemented set.)

mod common;

use zs_svd::coordinator::{self, Method};
use zs_svd::report::{acc2, f2, Table};

fn main() {
    let rt = common::runtime();
    let spec = common::spec();
    let ratio = 0.3; // paper: 30% pruning; testbed band (see EXPERIMENTS.md)

    let mut t = Table::new(
        "Table 2: 30%-pruning band (ratio 0.3) on llama + vicuna analogs",
        &["model", "method", "wiki2", "ptb", "c4", "avg-acc"],
    );

    for family in ["llama", "vicuna"] {
        let p = common::prepare(rt, "tiny", family, 7);
        let base = coordinator::evaluate_plan(&p, None, &spec).unwrap();
        t.row(vec![family.into(), "baseline".into(),
                   f2(base.ppl_of("wiki-syn")), f2(base.ppl_of("ptb-syn")),
                   f2(base.ppl_of("c4-syn")), acc2(base.avg_acc())]);
        for m in [Method::Asvd, Method::Fwsvd, Method::SvdLlm, Method::zs(ratio)] {
            let plan = coordinator::run_method(&p, &m, ratio).unwrap();
            let r = coordinator::evaluate_plan(&p, Some(&plan), &spec).unwrap();
            eprintln!("  {family}/{}: done", plan.method);
            t.row(vec![family.into(), plan.method.clone(),
                       f2(r.ppl_of("wiki-syn")), f2(r.ppl_of("ptb-syn")),
                       f2(r.ppl_of("c4-syn")), acc2(r.avg_acc())]);
        }
    }

    common::emit("table2_dipsvd_protocol", &t);
}
