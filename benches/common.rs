//! Shared plumbing for the table/figure bench harnesses.
//!
//! Each bench is a `harness = false` binary that regenerates one table or
//! figure of the paper (DESIGN.md §5) against the in-repo testbed, prints an
//! aligned ASCII table, and appends markdown to `results/`.
//! `ZS_BENCH_FAST=1` shrinks eval workloads for CI smoke runs.

#![allow(dead_code)]

use std::path::PathBuf;

use zs_svd::config::ExperimentConfig;
use zs_svd::coordinator::{self, Prepared};
use zs_svd::eval::EvalSpec;
use zs_svd::report::Table;
use zs_svd::runtime::Runtime;
use zs_svd::util::benchkit::fast_mode;

/// Leak the runtime so `Prepared` can borrow it for the bench's lifetime.
pub fn runtime() -> &'static Runtime {
    Box::leak(Box::new(
        Runtime::load_default().expect("run `make artifacts` first"),
    ))
}

/// Standard experiment configs keyed by (model, family, seed) — MUST match
/// what the pre-training step produced so checkpoints are reused.
pub fn exp(model: &str, family: &str, seed: u64) -> ExperimentConfig {
    ExperimentConfig {
        model: model.into(),
        family: family.into(),
        seed,
        ..ExperimentConfig::default()
    }
}

pub fn prepare(rt: &'static Runtime, model: &str, family: &str, seed: u64)
               -> Prepared<'static> {
    let mut cfg = exp(model, family, seed);
    if fast_mode() {
        // keep train_steps (checkpoints exist); shrink calibration only
        cfg.calib_batches = 2;
    }
    coordinator::prepare(rt, &cfg).expect("prepare")
}

pub fn spec() -> EvalSpec {
    if fast_mode() {
        EvalSpec { ppl_batches: 2, instances_per_family: 16, task_seed: 0xE1 }
    } else {
        EvalSpec { ppl_batches: 4, instances_per_family: 32, task_seed: 0xE1 }
    }
}

pub fn results_dir() -> PathBuf {
    let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("results");
    std::fs::create_dir_all(&d).ok();
    d
}

/// Headers for the split serving-phase token rates the decode/server
/// benches report: prefill = prompt tokens over the wall time of the
/// batched chunk-ingest calls alone; decode = the steady-state generation
/// rate over the batched decode-step sections alone.  One number per phase
/// makes the chunked-prefill win measurable instead of being averaged into
/// a single tok/s figure.
pub const PHASE_HEADERS: [&str; 2] = ["prefill tok/s", "decode tok/s"];

/// Cells matching [`PHASE_HEADERS`], from one engine run's phase rates.
pub fn phase_cells(prefill_tok_per_sec: f64, decode_tok_per_sec: f64)
                   -> Vec<String> {
    vec![zs_svd::report::f2(prefill_tok_per_sec),
         zs_svd::report::f2(decode_tok_per_sec)]
}

/// One phase counter (`phase.prefill_ns` etc.) accumulated since the last
/// `obs::reset()`, in milliseconds.  The scheduler ticks these on every
/// traced run; tracing is observe-only (`rust/tests/trace_equiv.rs`), so a
/// bench can leave it on without perturbing what it measures.
pub fn phase_ms(counter: &str) -> f64 {
    zs_svd::obs::counter(counter) as f64 / 1e6
}

/// Phase-breakdown JSON row for one traced engine run: wall milliseconds
/// the scheduler spent in each phase, read from the obs counters.
pub fn phase_row(engine: &str, speculate_k: usize,
                 decode_tok_per_sec: f64) -> zs_svd::util::json::Json {
    use zs_svd::util::json::Json;
    Json::obj(vec![
        ("engine", Json::str(engine)),
        ("speculate_k", Json::num(speculate_k as f64)),
        ("prefill_ms", Json::num(phase_ms("phase.prefill_ns"))),
        ("decode_ms", Json::num(phase_ms("phase.decode_ns"))),
        ("draft_ms", Json::num(phase_ms("phase.draft_ns"))),
        ("verify_ms", Json::num(phase_ms("phase.verify_ns"))),
        ("decode_tok_per_sec", Json::num(decode_tok_per_sec)),
    ])
}

/// Print + persist one table.
pub fn emit(name: &str, t: &Table) {
    print!("{}", t.to_ascii());
    let path = results_dir().join(format!("{name}.md"));
    // overwrite per run: one file per table keeps results fresh
    std::fs::write(&path, t.to_markdown()).expect("write results");
    println!("[saved {}]", path.display());
}
