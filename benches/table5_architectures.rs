//! Table 5 — 20% pruning (retention 0.8) across three architectures:
//! OPT-6.7B analog (`opt_tiny`), Vicuna-7B analog (tiny/vicuna weights), and
//! LLaMA-30B analog (`small`).  WikiText-2 PPL + average accuracy over six
//! commonsense tasks (excluding arc_c, per the paper).

mod common;

use zs_svd::coordinator::{self, Method};
use zs_svd::data::TaskFamily;
use zs_svd::eval;
use zs_svd::report::{acc2, f2, Table};
use zs_svd::util::benchkit::fast_mode;

const FAMS: [TaskFamily; 6] = [TaskFamily::OpenbSyn, TaskFamily::ArcESyn,
                               TaskFamily::WinogSyn, TaskFamily::HellasSyn,
                               TaskFamily::PiqaSyn, TaskFamily::MathqaSyn];

fn main() {
    let rt = common::runtime();
    let spec = common::spec();
    let ratio = 0.35; // paper band 0.8 (20% pruning)

    let mut t = Table::new(
        "Table 5: 20% pruning across architectures",
        &["arch", "method", "ppl(wiki)", "acc(6)"],
    );

    let setups = [("opt_tiny", "llama", 7, "opt-analog"),
                  ("tiny", "vicuna", 7, "vicuna-analog"),
                  ("small", "llama", 7, "30B-analog")];
    for (model, family, seed, label) in setups {
        let p = common::prepare(rt, model, family, seed);
        let eval_subset = |params: &zs_svd::model::ParamStore| {
            eval::evaluate_subset(&p.session, params, &p.eval_corpora, &p.world,
                                  &spec, &FAMS).unwrap()
        };
        let base = eval_subset(&p.params);
        t.row(vec![label.into(), "original".into(),
                   f2(base.ppl_of("wiki-syn")), acc2(base.avg_acc())]);
        let mut methods = vec![Method::Svd, Method::Fwsvd, Method::Asvd,
                               Method::SvdLlm, Method::zs(ratio)];
        if fast_mode() {
            methods = vec![Method::Svd, Method::zs(ratio)];
        }
        for m in methods {
            let plan = coordinator::run_method(&p, &m, ratio).unwrap();
            let r = eval_subset(&plan.apply(&p.params));
            eprintln!("  {label}/{}: done", plan.method);
            t.row(vec![label.into(), plan.method.clone(),
                       f2(r.ppl_of("wiki-syn")), acc2(r.avg_acc())]);
        }
    }

    common::emit("table5_architectures", &t);
}
