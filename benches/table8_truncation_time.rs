//! Table 8 — end-to-end truncation time vs quality at retention 0.4:
//! SVD-LLM (whitening only) vs Dobi-sim (optimization-heavy) vs ZS-SVD
//! (whitening + gradients + zero-sum).  Times include each method's own
//! calibration share: SVD-LLM pays the moments pass, ZS-SVD additionally
//! pays the gradient pass, Dobi pays moments + its search-loop forwards.

mod common;

use zs_svd::coordinator::{self, Method};
use zs_svd::report::{f2, Table};
use zs_svd::util::benchkit::fmt_duration;

fn main() {
    let rt = common::runtime();
    let p = common::prepare(rt, "tiny", "llama", 7);
    let spec = common::spec();
    let ratio = 0.15; // paper band 0.4

    let mut t = Table::new(
        "Table 8: truncation time vs wiki PPL (paper band 0.4 = ratio 0.15)",
        &["method", "calib share", "compress", "total", "ppl(wiki)"],
    );

    let rows: Vec<(Method, f64)> = vec![
        // (method, extra calibration seconds the method requires)
        (Method::SvdLlm, p.calib.moments_seconds),
        // the real Dobi-SVD spends hours in its differentiable rank search;
        // the simulator's sweep count is the cost dial (DESIGN.md §2)
        (Method::DobiSim { sweeps: 8 }, p.calib.moments_seconds),
        (Method::zs(ratio), p.calib.moments_seconds + p.calib.grads_seconds),
    ];
    for (m, calib_share) in rows {
        let plan = coordinator::run_method(&p, &m, ratio).unwrap();
        let r = coordinator::evaluate_plan(&p, Some(&plan), &spec).unwrap();
        let total = calib_share + plan.seconds;
        eprintln!("  {}: {} (ppl {:.2})", plan.method, fmt_duration(total),
                  r.ppl_of("wiki-syn"));
        t.row(vec![plan.method.clone(), fmt_duration(calib_share),
                   fmt_duration(plan.seconds), fmt_duration(total),
                   f2(r.ppl_of("wiki-syn"))]);
    }

    common::emit("table8_truncation_time", &t);
}
