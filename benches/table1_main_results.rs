//! Table 1 — main results on the LLaMA-7B analog: SVD-family baselines vs
//! ZS-SVD (+ corrections, remap*, HQ†) at retention 0.8 / 0.6 / 0.4.
//! Columns: PPL on the three corpora, per-task accuracy, average, drop%.

mod common;

use zs_svd::coordinator::{self, Method};
use zs_svd::report::{acc2, f2, pct, Table};
use zs_svd::util::benchkit::fast_mode;

fn main() {
    let rt = common::runtime();
    let p = common::prepare(rt, "tiny", "llama", 7);
    let spec = common::spec();
    let base = coordinator::evaluate_plan(&p, None, &spec).unwrap();

    let mut headers = vec!["ratio".to_string(), "method".into(),
                           "wiki2".into(), "ptb".into(), "c4".into()];
    for (n, _) in &base.acc {
        headers.push(n.clone());
    }
    headers.push("avg".into());
    headers.push("drop%".into());
    let mut t = Table::new("Table 1: ZS-SVD vs SVD baselines (tiny = LLaMA-7B analog)",
                           &headers.iter().map(|s| s.as_str()).collect::<Vec<_>>());

    let mut push_row = |ratio: &str, label: &str, r: &zs_svd::eval::EvalReport| {
        let mut row = vec![ratio.to_string(), label.to_string(),
                           f2(r.ppl_of("wiki-syn")), f2(r.ppl_of("ptb-syn")),
                           f2(r.ppl_of("c4-syn"))];
        for (_, a) in &r.acc {
            row.push(acc2(*a));
        }
        row.push(acc2(r.avg_acc()));
        row.push(pct(r.drop_vs(&base)));
        t.row(row);
    };
    push_row("1.0", "baseline", &base);

    // paper bands 0.8/0.6/0.4 -> testbed bands 0.35/0.25/0.15
    // (our ~1M-param models are far more compressible; see EXPERIMENTS.md)
    let ratios: &[f64] = if fast_mode() { &[0.25] } else { &[0.35, 0.25, 0.15] };
    for &ratio in ratios {
        let mut methods: Vec<Method> = vec![
            Method::Asvd,
            Method::SvdLlm,
            Method::DobiSim { sweeps: 1 },
            Method::zs(ratio),
            Method::zs_corrected(ratio, 1),
            Method::zs_corrected(ratio, 5),
        ];
        if ratio <= 0.16 {
            methods.push(Method::zs_corrected(ratio, 10));
        }
        // footprint-matched rows: remap above 50% retention, HQ below
        methods.push(Method::DobiSimRemap { sweeps: 1 });
        if ratio >= 0.25 {
            methods.push(Method::zs_remap(ratio));
        } else {
            methods.push(Method::zs_hq(ratio));
        }
        if fast_mode() {
            methods.truncate(4);
        }
        for m in methods {
            let plan = coordinator::run_method(&p, &m, ratio).unwrap();
            let r = coordinator::evaluate_plan(&p, Some(&plan), &spec).unwrap();
            eprintln!("  ratio {ratio} {}: done ({:.1}s)", plan.method, plan.seconds);
            push_row(&format!("{ratio}"), &plan.method, &r);
        }
    }

    common::emit("table1_main_results", &t);
}
