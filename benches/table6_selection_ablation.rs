//! Table 6 — ablation of global σ-selection strategies on the LLaMA-7B
//! analog: most-negative ΔL / |ΔL| (each with and without per-W spectral
//! order), smallest σ, and the zero-sum rule.  WikiText-2 PPL at retention
//! 0.4 and 0.6.

mod common;

use zs_svd::compress::{Strategy};
use zs_svd::coordinator::{self, Method};
use zs_svd::report::{f2, Table};

fn main() {
    let rt = common::runtime();
    let p = common::prepare(rt, "tiny", "llama", 7);
    let spec = common::spec();

    let strategies: Vec<(&str, &str, Strategy)> = vec![
        ("most-negative dL", "no",
         Strategy::MostNegative { per_w_order: false }),
        ("|dL|", "no", Strategy::MagnitudeDl { per_w_order: false }),
        ("most-negative dL", "yes",
         Strategy::MostNegative { per_w_order: true }),
        ("|dL|", "yes", Strategy::MagnitudeDl { per_w_order: true }),
        ("sigma", "yes", Strategy::SigmaSmallest),
        ("zero-sum dL (ZS-SVD)", "yes", Strategy::ZeroSum),
    ];

    let mut t = Table::new(
        "Table 6: global sigma-selection strategy ablation (wiki PPL)",
        &["strategy", "per-W order", "ratio 0.15 (~0.4)", "ratio 0.25 (~0.6)"],
    );

    for (label, ordered, strat) in strategies {
        let mut ppls = Vec::new();
        for ratio in [0.15, 0.25] { // paper bands 0.4 / 0.6
            let m = Method::zs_strategy(ratio, strat);
            let plan = coordinator::run_method(&p, &m, ratio).unwrap();
            let r = coordinator::evaluate_plan(&p, Some(&plan), &spec).unwrap();
            ppls.push(r.ppl_of("wiki-syn"));
            eprintln!("  {label} ({ordered}) @ {ratio}: {:.2}", ppls.last().unwrap());
        }
        t.row(vec![label.into(), ordered.into(), f2(ppls[0]), f2(ppls[1])]);
    }

    common::emit("table6_selection_ablation", &t);
}
