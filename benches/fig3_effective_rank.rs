//! Figures 3 & 4 — gradient vs weight effective rank under 20% pruning.
//!
//! Truncate the model at retention 0.8, take a single backward pass on a
//! small calibration minibatch at the truncated point (the paper uses 4
//! sequences), and report k_0.95(G) / k_0.95(W') per module for the first,
//! middle and last layers (Fig. 3).  `--spectra` additionally dumps the
//! normalized singular spectra (Fig. 4 series) to results/.

mod common;

use zs_svd::coordinator::{self, Method};
use zs_svd::linalg::{effective_rank, svd};
use zs_svd::report::{f2, Table};

fn main() {
    let dump_spectra = std::env::args().any(|a| a == "--spectra")
        || !zs_svd::util::benchkit::fast_mode();
    let rt = common::runtime();
    let p = common::prepare(rt, "tiny", "llama", 7);
    let ratio = 0.35; // paper band 0.8 (20% pruning)

    let plan = coordinator::run_method(&p, &Method::zs(ratio), ratio).unwrap();
    let compressed = plan.apply(&p.params);
    // single backward pass on one calibration minibatch
    let (_, grads) = p.session.grads(&compressed, &p.calib.batches[0]).unwrap();

    let layers = [0usize, p.session.cfg.n_layers / 2, p.session.cfg.n_layers - 1];
    let mut t = Table::new(
        "Fig 3: effective rank k0.95 of gradients vs truncated weights",
        &["layer", "module", "k095(W')", "k095(G)", "ratio G/W'"],
    );

    let mut spectra = String::new();
    for &li in &layers {
        let prefix = format!("layers.{li}.");
        for target in &p.session.cfg.targets {
            if !target.name.starts_with(&prefix) {
                continue;
            }
            let w = compressed.get(&target.name).to_mat();
            let g = &grads[&target.name];
            let sw = svd(&w);
            let sg = svd(g);
            let kw = effective_rank(&sw.sigma, 0.95);
            let kg = effective_rank(&sg.sigma, 0.95);
            let module = target.name.rsplit('.').next().unwrap();
            t.row(vec![format!("{li}"), module.into(), format!("{kw}"),
                       format!("{kg}"), f2(kg as f64 / kw.max(1) as f64)]);
            if dump_spectra {
                let norm = |s: &[f32]| -> Vec<f32> {
                    let m = s.first().copied().unwrap_or(1.0).max(1e-12);
                    s.iter().map(|&x| x / m).collect()
                };
                spectra.push_str(&format!(
                    "layer {li} {module} W' {:?}\nlayer {li} {module} G {:?}\n",
                    norm(&sw.sigma), norm(&sg.sigma)
                ));
            }
        }
    }

    common::emit("fig3_effective_rank", &t);
    if dump_spectra {
        let path = common::results_dir().join("fig4_spectra.txt");
        std::fs::write(&path, spectra).unwrap();
        println!("[saved {}]", path.display());
    }
}
