//! Network serving throughput — dense vs ZS-SVD low-rank engines behind the
//! TCP front-end, measured end-to-end from loopback clients (socket + wire
//! protocol + admission + continuous-batching decode), the network-side
//! companion of `decode_throughput`.
//!
//! Each engine serves the SAME closed-loop client fleet: C connections,
//! each sending R greedy generation requests back-to-back and reading its
//! token stream.  Reported latencies are the server's own end-to-end
//! summaries (enqueue → completion, the shared p50/p95/p99/mean shape);
//! prefill and decode phases are reported as separate token rates
//! (`common::PHASE_HEADERS`).  The zs-svd engine additionally sweeps the
//! `prefill_chunk` knob — prompt tokens ingested per scheduler iteration —
//! so the chunked-prefill batching win is visible directly: bigger chunks
//! put more rows into each prefill GEMM and the prefill tok/s column rises
//! with them (tokens streamed to clients are identical for every chunk
//! size; `rust/tests/server_loopback.rs` gates that bit-exactly).
//!
//! The harness runs with tracing on (observe-only — the streamed tokens
//! cannot change) and pulls one wire `trace` snapshot per server run, so
//! the protocol-side observability path is exercised under real load.
//!
//! The second half replays a **repeated-prefix fleet**: every request
//! shares one long prompt prefix (the fleet-traffic shape prefix caching
//! targets), served once with the prefix cache off and once with it on.
//! Tokens are bit-identical either way (`rust/tests/prefix_cache.rs` gates
//! that), so the sweep isolates the serving effect — client-observed TTFT
//! and prefill tok/s — and records it machine-readably in `BENCH_8.json`
//! at the repo root.
//!
//! The third section shards the SAME packed artifact behind the supervised
//! fleet router (`zs_svd::fleet`) at worker counts {1, 2, 4}: real worker
//! processes spawned from this build's own binary, the closed-loop client
//! fleet driven through one routed address, wall-clock throughput measured
//! client-side after all workers report healthy (so process boot is not
//! charged to the serving tier).  Streamed tokens are bit-identical at
//! every worker count (`rust/tests/fleet.rs` gates that), so the sweep
//! isolates the availability/throughput effect of sharding.  Results land
//! in `BENCH_10.json` at the repo root.

mod common;

use std::net::SocketAddr;
use std::path::PathBuf;
use std::sync::mpsc;
use std::time::Instant;

use zs_svd::artifact::pack;
use zs_svd::coordinator::{self, Method, Prepared};
use zs_svd::decode::{synth_requests_shared_prefix, DecodeConfig,
                     DEFAULT_KV_BLOCK};
use zs_svd::fleet::{run_fleet, FleetStats, RouterConfig};
use zs_svd::report::{f2, latency_cells, Table, LATENCY_HEADERS};
use zs_svd::serve::Engine;
use zs_svd::server::{self, Client, GenerateOutcome, GenerateReq,
                     ServerConfig, ServerStats};
use zs_svd::util::benchkit::fast_mode;
use zs_svd::util::json::Json;
use zs_svd::util::stats::LatencySummary;

struct Load {
    clients: usize,
    per_client: usize,
    prompt_len: usize,
    max_new: usize,
}

fn drive(p: &Prepared, params: &zs_svd::model::ParamStore, engine: &Engine,
         load: &Load, prefill_chunk: usize) -> ServerStats {
    let cfg = ServerConfig {
        addr: "127.0.0.1:0".into(),
        queue_depth: 128,
        decode: DecodeConfig { max_slots: 4, max_new_tokens: load.max_new,
                               temperature: 0.0, seed: 1, arrival_steps: 0.0,
                               prefill_chunk, speculate_k: 0,
                               ..DecodeConfig::default() },
    };
    let vocab = p.session.cfg.vocab;
    let (tx, rx) = mpsc::channel::<SocketAddr>();
    let sess = &p.session;

    std::thread::scope(|s| {
        let cfg = &cfg;
        let srv = s.spawn(move || {
            server::run(sess, params, engine, None, cfg, move |a| {
                tx.send(a).expect("report addr");
            })
        });
        let addr = rx.recv().expect("server bound");

        let handles: Vec<_> = (0..load.clients)
            .map(|c| {
                s.spawn(move || {
                    let mut cl = Client::connect(addr).expect("connect");
                    for i in 0..load.per_client {
                        let k = c * load.per_client + i;
                        let prompt =
                            server::scripted_prompt(k, load.prompt_len, vocab);
                        let g = GenerateReq { id: k as u64, prompt,
                                              max_new_tokens: load.max_new,
                                              temperature: None, seed: None };
                        match cl.run_generate(&g).expect("generate") {
                            GenerateOutcome::Done(r) => {
                                assert_eq!(r.tokens.len(), load.max_new);
                            }
                            GenerateOutcome::Rejected { code, message, .. }
                            => {
                                panic!("request {k} rejected: {code} \
                                        ({message})");
                            }
                        }
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().expect("client thread");
        }

        let mut cl = Client::connect(addr).expect("connect for shutdown");
        // one wire trace snapshot per run: tracing is on (see main), so
        // the served load must have left events and gauges behind
        let snap = cl.trace().expect("trace snapshot");
        assert_eq!(snap.str_or("type", ""), "trace");
        assert!(snap.bool_or("enabled", false), "bench enables tracing");
        assert!(snap.get("events").and_then(|e| e.as_arr())
                    .map(|a| !a.is_empty()).unwrap_or(false),
                "traced serving left no events in the ring");
        cl.shutdown_server().expect("shutdown");
        srv.join().expect("server thread").expect("server run")
    })
}

/// One request of the shared-prefix fleet, as the client observed it:
/// time to first token and how many prompt tokens the server reported
/// serving from its prefix cache.
fn run_prefix_request(cl: &mut Client, prompts: &[Vec<i32>], k: usize,
                      max_new: usize) -> (f64, usize) {
    let g = GenerateReq { id: k as u64, prompt: prompts[k].clone(),
                          max_new_tokens: max_new,
                          temperature: None, seed: None };
    match cl.run_generate(&g).expect("generate") {
        GenerateOutcome::Done(r) => {
            assert_eq!(r.tokens.len(), max_new);
            (r.ttft_ms, r.cached_prompt_tokens)
        }
        GenerateOutcome::Rejected { code, message, .. } => {
            panic!("prefix request {k} rejected: {code} ({message})");
        }
    }
}

/// Shared-prefix fleet driver: request 0 runs alone as the cold warmup
/// (with caching on it leaves the common prefix in the tree), then the
/// remaining prompts are round-robined over `clients` concurrent
/// connections.  Returns the server's own stats plus the fleet's
/// client-side TTFTs and per-request cached-prompt-token counts
/// (warmup excluded from both vectors).
fn drive_prefix(p: &Prepared, params: &zs_svd::model::ParamStore,
                engine: &Engine, prompts: &[Vec<i32>], clients: usize,
                max_new: usize, prefix_blocks: usize)
                -> (ServerStats, Vec<f64>, Vec<usize>) {
    let cfg = ServerConfig {
        addr: "127.0.0.1:0".into(),
        queue_depth: 128,
        decode: DecodeConfig { max_slots: 4, max_new_tokens: max_new,
                               temperature: 0.0, seed: 1, arrival_steps: 0.0,
                               prefill_chunk: 0, speculate_k: 0,
                               prefix_cache_blocks: prefix_blocks,
                               ..DecodeConfig::default() },
    };
    let (tx, rx) = mpsc::channel::<SocketAddr>();
    let sess = &p.session;

    std::thread::scope(|s| {
        let cfg = &cfg;
        let srv = s.spawn(move || {
            server::run(sess, params, engine, None, cfg, move |a| {
                tx.send(a).expect("report addr");
            })
        });
        let addr = rx.recv().expect("server bound");

        let mut warm = Client::connect(addr).expect("connect warmup");
        let (_, warm_cached) = run_prefix_request(&mut warm, prompts, 0,
                                                  max_new);
        assert_eq!(warm_cached, 0, "cold warmup cannot hit the cache");
        drop(warm);

        let (rtx, rrx) = mpsc::channel::<(f64, usize)>();
        let fleet: Vec<_> = (0..clients)
            .map(|c| {
                let rtx = rtx.clone();
                s.spawn(move || {
                    let mut cl = Client::connect(addr).expect("connect");
                    for k in 1..prompts.len() {
                        if (k - 1) % clients != c {
                            continue;
                        }
                        let out = run_prefix_request(&mut cl, prompts, k,
                                                     max_new);
                        rtx.send(out).expect("report result");
                    }
                })
            })
            .collect();
        drop(rtx);
        for h in fleet {
            h.join().expect("fleet client thread");
        }
        let (mut ttfts, mut cached) = (Vec::new(), Vec::new());
        for (t, c) in rrx.iter() {
            ttfts.push(t);
            cached.push(c);
        }

        let mut cl = Client::connect(addr).expect("connect for shutdown");
        cl.shutdown_server().expect("shutdown");
        let stats = srv.join().expect("server thread").expect("server run");
        (stats, ttfts, cached)
    })
}

/// Drive the closed-loop client fleet through a supervised router in front
/// of `workers` worker processes serving `manifest`.  Returns the timed
/// window's wall-clock ms (first request sent → last stream read, after
/// every worker reported healthy) and the fleet's lifetime stats.
fn drive_fleet(manifest: &std::path::Path, workers: usize, load: &Load,
               vocab: usize) -> (f64, FleetStats) {
    let mut cfg = RouterConfig::new(
        "127.0.0.1:0", workers,
        vec![manifest.to_str().expect("utf8 manifest path").to_string()]);
    cfg.program = PathBuf::from(env!("CARGO_BIN_EXE_zs-svd"));
    cfg.worker_args = vec!["--threads".into(), "1".into()];
    let (tx, rx) = mpsc::channel::<SocketAddr>();
    let router = std::thread::spawn(move || {
        run_fleet(cfg, move |a| { tx.send(a).expect("report addr"); })
    });
    let addr = rx.recv().expect("fleet bound");

    // wait out worker boot so the timed window measures serving, not
    // process spawn + artifact load
    let mut ctrl = Client::connect(addr).expect("connect control");
    loop {
        let snap = ctrl.metrics().expect("metrics");
        let ws = snap.get("workers").and_then(|w| w.as_arr())
            .expect("fleet snapshot");
        if ws.iter().all(|w| w.bool_or("healthy", false)) {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(25));
    }

    let t0 = Instant::now();
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..load.clients)
            .map(|c| {
                s.spawn(move || {
                    let mut cl = Client::connect(addr).expect("connect");
                    for i in 0..load.per_client {
                        let k = c * load.per_client + i;
                        let prompt =
                            server::scripted_prompt(k, load.prompt_len, vocab);
                        let g = GenerateReq { id: k as u64, prompt,
                                              max_new_tokens: load.max_new,
                                              temperature: None, seed: None };
                        match cl.run_generate(&g).expect("generate") {
                            GenerateOutcome::Done(r) => {
                                assert_eq!(r.tokens.len(), load.max_new);
                            }
                            GenerateOutcome::Rejected { code, message, .. }
                            => {
                                panic!("fleet request {k} rejected: {code} \
                                        ({message})");
                            }
                        }
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().expect("fleet client thread");
        }
    });
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;

    ctrl.shutdown_server().expect("shutdown");
    let stats = router.join().expect("router thread").expect("fleet run");
    (wall_ms, stats)
}

/// Human label for a `prefill_chunk` setting (0 = whole prompt per
/// iteration).
fn chunk_label(prefill_chunk: usize) -> String {
    if prefill_chunk == 0 {
        "full".into()
    } else {
        format!("{prefill_chunk}")
    }
}

fn main() {
    let rt = common::runtime();
    let p = common::prepare(rt, "tiny", "llama", 7);
    // observe-only (rust/tests/trace_equiv.rs): on for the whole bench so
    // every drive() can pull a populated wire trace snapshot
    zs_svd::obs::set_enabled(true);
    let load = if fast_mode() {
        Load { clients: 2, per_client: 2, prompt_len: 8, max_new: 6 }
    } else {
        Load { clients: 4, per_client: 6, prompt_len: 16, max_new: 16 }
    };

    let mut headers = vec!["engine", "compression", "chunk"];
    headers.extend(common::PHASE_HEADERS);
    headers.extend(LATENCY_HEADERS);
    headers.extend(["ttft p50 ms", "rejected"]);
    let mut t = Table::new(
        "server throughput (TCP loopback, streaming decode)", &headers);

    let mut emit_row = |label: &str, comp: &str, chunk: usize,
                        s: &ServerStats| {
        // steady-state decode rate (decode-step sections only) next to the
        // prefill-phase rate — the same split definitions decode_throughput
        // reports.  NOT tokens over the whole wall clock, which would
        // charge connect gaps and the drain to the TCP tier
        let pre = s.counters.prefill_tok_per_sec();
        let dec = s.counters.decode_tok_per_sec();
        eprintln!("  {label}@{comp} chunk {}: {pre:.0} prefill tok/s, \
                   {dec:.0} decode tok/s over TCP",
                  chunk_label(chunk));
        let mut row = vec![label.to_string(), comp.to_string(),
                           chunk_label(chunk)];
        row.extend(common::phase_cells(pre, dec));
        row.extend(latency_cells(&s.e2e));
        row.extend([f2(s.ttft.p50), format!("{}", s.requests_rejected)]);
        t.row(row);
    };

    let d = drive(&p, &p.params, &Engine::Dense, &load, 0);
    emit_row("original", "0%", 0, &d);

    // the zs-svd engine sweeps the prefill chunk: tokens are identical at
    // every size, so the prefill tok/s column isolates the batching win
    // (1 ≈ the old token-at-a-time path, full = whole-prompt GEMMs)
    let chunk_sweep = [1usize, 4, 0];
    for (i, (comp, ratio)) in [("40%", 0.6), ("60%", 0.4)].iter().enumerate() {
        let plan = coordinator::run_method(&p, &Method::zs(*ratio), *ratio)
            .expect("compress");
        let tag = format!("{}", (ratio * 100.0) as usize);
        let lm = p.session.cfg.lowrank.get(&tag).expect("artifact tag");
        let engine = Engine::from_plan_capped(&tag, &plan, &lm.ranks);
        let params = plan.apply(&p.params);
        if i == 0 {
            for &chunk in &chunk_sweep {
                let s = drive(&p, &params, &engine, &load, chunk);
                emit_row(&plan.method, comp, chunk, &s);
            }
        } else {
            let s = drive(&p, &params, &engine, &load, 0);
            emit_row(&plan.method, comp, 0, &s);
        }
    }

    common::emit("server_throughput", &t);

    // ---------------------------------------------------------------
    // repeated-prefix fleet (BENCH_8): every request shares one long
    // prompt prefix — the traffic shape the paged KV pool's prefix tree
    // targets.  Served cache-off then cache-on through the SAME dense
    // engine; streamed tokens are bit-identical either way
    // (rust/tests/prefix_cache.rs gates that), so the delta is pure
    // serving effect.  The prefix is block-aligned and capped well below
    // tiny's seq_len so every prompt + generation budget fits the KV
    // capacity; `drive_prefix` asserts the server reports exactly the
    // shared prefix as cached for every warm request.
    // ---------------------------------------------------------------
    let scfg = &p.session.cfg;
    let block = DEFAULT_KV_BLOCK;
    let prefix_len = (scfg.seq_len * 3 / 4) / block * block;
    let suffix_len = block / 2;
    let (fleet_n, fleet_clients, fleet_new) = if fast_mode() {
        (8usize, 2usize, 4usize)
    } else {
        (64, 4, 8)
    };
    assert!(prefix_len + suffix_len + fleet_new <= scfg.seq_len);
    // +1: request 0 is the cold warmup, the fleet is the remaining n
    let reqs = synth_requests_shared_prefix(scfg, fleet_n + 1, prefix_len,
                                            suffix_len, fleet_new, 0xCAFE);
    let prompts: Vec<Vec<i32>> = reqs.into_iter().map(|r| r.prompt).collect();

    let mut pt = Table::new(
        "repeated-prefix fleet (shared prompt prefix, dense engine)",
        &["prefix cache", "ttft p50 ms", "ttft mean ms", "prefill tok/s",
          "cached tok/req", "hit tok", "miss tok"],
    );
    let mut bench8_rows: Vec<Json> = Vec::new();
    for &blocks in &[0usize, 64] {
        let label = if blocks == 0 { "off" } else { "on" };
        let (s, ttfts, cached) =
            drive_prefix(&p, &p.params, &Engine::Dense, &prompts,
                         fleet_clients, fleet_new, blocks);
        if blocks == 0 {
            assert!(cached.iter().all(|&c| c == 0),
                    "cache off must never report cached prompt tokens");
        } else {
            // the warmup inserted the aligned shared prefix, so every
            // fleet request skips prefill for exactly those tokens
            assert!(cached.iter().all(|&c| c == prefix_len),
                    "warm requests must hit the full shared prefix \
                     ({prefix_len} tokens): {cached:?}");
        }
        let ttft = LatencySummary::from_samples(&ttfts);
        let hit = s.counters.prefix_hit_tokens;
        let miss = s.counters.prefix_miss_tokens;
        let cached_per_req = if cached.is_empty() {
            0.0
        } else {
            cached.iter().sum::<usize>() as f64 / cached.len() as f64
        };
        let pre = s.counters.prefill_tok_per_sec();
        eprintln!("  prefix cache {label}: ttft p50 {:.2} ms, \
                   {pre:.0} prefill tok/s, {hit} hit / {miss} miss tokens",
                  ttft.p50);
        pt.row(vec![label.into(), f2(ttft.p50), f2(ttft.mean), f2(pre),
                    f2(cached_per_req), format!("{hit}"),
                    format!("{miss}")]);
        bench8_rows.push(Json::obj(vec![
            ("prefix_cache", Json::str(label)),
            ("prefix_cache_blocks", Json::num(blocks as f64)),
            ("requests", Json::num(fleet_n as f64)),
            ("clients", Json::num(fleet_clients as f64)),
            ("prefix_len", Json::num(prefix_len as f64)),
            ("suffix_len", Json::num(suffix_len as f64)),
            ("ttft_p50_ms", Json::num(ttft.p50)),
            ("ttft_mean_ms", Json::num(ttft.mean)),
            ("prefill_tok_per_sec", Json::num(pre)),
            ("cached_prompt_tokens_per_request", Json::num(cached_per_req)),
            ("prefix_hit_tokens", Json::num(hit as f64)),
            ("prefix_miss_tokens", Json::num(miss as f64)),
            ("prefix_evictions", Json::num(s.counters.prefix_evictions
                                               as f64)),
        ]));
    }
    common::emit("server_prefix_cache", &pt);

    let bench8 = Json::obj(vec![
        ("bench", Json::str("server_throughput/prefix_cache")),
        ("generated_by",
         Json::str("cargo bench --bench server_throughput (also run by \
                    ci.sh)")),
        ("fast_mode", Json::Bool(fast_mode())),
        ("units", Json::str("client-observed TTFT over the warm fleet \
                             (cold warmup request excluded); prefill \
                             tok/s from the scheduler's prefill-section \
                             wall time; streamed tokens bit-identical \
                             cache on or off")),
        ("results", Json::Arr(bench8_rows)),
    ]);
    let bench8_path = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("BENCH_8.json");
    std::fs::write(&bench8_path, bench8.to_string_pretty() + "\n")
        .expect("write BENCH_8.json");
    println!("[saved {}]", bench8_path.display());

    // ---------------------------------------------------------------
    // fleet sweep (BENCH_10): one packed ZS-SVD artifact behind the
    // supervised router at 1/2/4 worker processes.  Tokens are identical
    // at every worker count (rust/tests/fleet.rs gates that bit-exactly),
    // so the columns isolate what sharding buys: wall-clock throughput of
    // the same closed-loop fleet, plus the router's own routed/restart
    // counters (restarts must be 0 — no faults are injected here).
    // ---------------------------------------------------------------
    let plan = coordinator::run_method(&p, &Method::zs(0.6), 0.6)
        .expect("compress for fleet sweep");
    let tag = "60".to_string();
    let lm = p.session.cfg.lowrank.get(&tag).expect("artifact tag");
    let engine = Engine::from_plan_capped(&tag, &plan, &lm.ranks);
    let params = plan.apply(&p.params);
    let store = std::env::temp_dir()
        .join(format!("zs_bench_fleet_{}", std::process::id()));
    std::fs::remove_dir_all(&store).ok();
    let manifest = pack(&p.session.cfg, &params, &engine, None, &store,
                        "fleet-bench").expect("pack fleet artifact");

    let vocab = p.session.cfg.vocab;
    let total_tokens = (load.clients * load.per_client * load.max_new) as f64;
    let mut ft = Table::new(
        "fleet serving (supervised router, real worker processes)",
        &["workers", "wall ms", "tok/s", "routed", "restarts"]);
    let mut bench10_rows: Vec<Json> = Vec::new();
    for &workers in &[1usize, 2, 4] {
        let (wall_ms, stats) = drive_fleet(&manifest, workers, &load, vocab);
        let tps = total_tokens / (wall_ms / 1e3);
        assert_eq!(stats.worker_restarts, 0, "no faults injected");
        eprintln!("  fleet x{workers}: {tps:.0} tok/s end-to-end \
                   ({wall_ms:.0} ms wall)");
        ft.row(vec![format!("{workers}"), f2(wall_ms), f2(tps),
                    format!("{}", stats.requests_routed),
                    format!("{}", stats.worker_restarts)]);
        bench10_rows.push(Json::obj(vec![
            ("workers", Json::num(workers as f64)),
            ("clients", Json::num(load.clients as f64)),
            ("requests", Json::num((load.clients * load.per_client) as f64)),
            ("max_new_tokens", Json::num(load.max_new as f64)),
            ("wall_ms", Json::num(wall_ms)),
            ("tok_per_sec", Json::num(tps)),
            ("requests_routed", Json::num(stats.requests_routed as f64)),
            ("worker_restarts", Json::num(stats.worker_restarts as f64)),
        ]));
    }
    common::emit("server_fleet", &ft);
    std::fs::remove_dir_all(&store).ok();

    let bench10 = Json::obj(vec![
        ("bench", Json::str("server_throughput/fleet")),
        ("generated_by",
         Json::str("cargo bench --bench server_throughput (also run by \
                    ci.sh)")),
        ("fast_mode", Json::Bool(fast_mode())),
        ("units", Json::str("end-to-end tok/s of the whole closed-loop \
                             client fleet through the routed address, \
                             timed after every worker process reported \
                             healthy; streamed tokens bit-identical at \
                             every worker count")),
        ("results", Json::Arr(bench10_rows)),
    ]);
    let bench10_path = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("BENCH_10.json");
    std::fs::write(&bench10_path, bench10.to_string_pretty() + "\n")
        .expect("write BENCH_10.json");
    println!("[saved {}]", bench10_path.display());
}
