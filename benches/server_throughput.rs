//! Network serving throughput — dense vs ZS-SVD low-rank engines behind the
//! TCP front-end, measured end-to-end from loopback clients (socket + wire
//! protocol + admission + continuous-batching decode), the network-side
//! companion of `decode_throughput`.
//!
//! Each engine serves the SAME closed-loop client fleet: C connections,
//! each sending R greedy generation requests back-to-back and reading its
//! token stream.  Reported latencies are the server's own end-to-end
//! summaries (enqueue → completion, the shared p50/p95/p99/mean shape);
//! prefill and decode phases are reported as separate token rates
//! (`common::PHASE_HEADERS`).  The zs-svd engine additionally sweeps the
//! `prefill_chunk` knob — prompt tokens ingested per scheduler iteration —
//! so the chunked-prefill batching win is visible directly: bigger chunks
//! put more rows into each prefill GEMM and the prefill tok/s column rises
//! with them (tokens streamed to clients are identical for every chunk
//! size; `rust/tests/server_loopback.rs` gates that bit-exactly).
//!
//! The harness runs with tracing on (observe-only — the streamed tokens
//! cannot change) and pulls one wire `trace` snapshot per server run, so
//! the protocol-side observability path is exercised under real load.

mod common;

use std::net::SocketAddr;
use std::sync::mpsc;

use zs_svd::coordinator::{self, Method, Prepared};
use zs_svd::decode::DecodeConfig;
use zs_svd::report::{f2, latency_cells, Table, LATENCY_HEADERS};
use zs_svd::serve::Engine;
use zs_svd::server::{self, Client, GenerateOutcome, GenerateReq,
                     ServerConfig, ServerStats};
use zs_svd::util::benchkit::fast_mode;

struct Load {
    clients: usize,
    per_client: usize,
    prompt_len: usize,
    max_new: usize,
}

fn drive(p: &Prepared, params: &zs_svd::model::ParamStore, engine: &Engine,
         load: &Load, prefill_chunk: usize) -> ServerStats {
    let cfg = ServerConfig {
        addr: "127.0.0.1:0".into(),
        queue_depth: 128,
        decode: DecodeConfig { max_slots: 4, max_new_tokens: load.max_new,
                               temperature: 0.0, seed: 1, arrival_steps: 0.0,
                               prefill_chunk, speculate_k: 0 },
    };
    let vocab = p.session.cfg.vocab;
    let (tx, rx) = mpsc::channel::<SocketAddr>();
    let sess = &p.session;

    std::thread::scope(|s| {
        let cfg = &cfg;
        let srv = s.spawn(move || {
            server::run(sess, params, engine, None, cfg, move |a| {
                tx.send(a).expect("report addr");
            })
        });
        let addr = rx.recv().expect("server bound");

        let handles: Vec<_> = (0..load.clients)
            .map(|c| {
                s.spawn(move || {
                    let mut cl = Client::connect(addr).expect("connect");
                    for i in 0..load.per_client {
                        let k = c * load.per_client + i;
                        let prompt =
                            server::scripted_prompt(k, load.prompt_len, vocab);
                        let g = GenerateReq { id: k as u64, prompt,
                                              max_new_tokens: load.max_new,
                                              temperature: None, seed: None };
                        match cl.run_generate(&g).expect("generate") {
                            GenerateOutcome::Done(r) => {
                                assert_eq!(r.tokens.len(), load.max_new);
                            }
                            GenerateOutcome::Rejected { code, message } => {
                                panic!("request {k} rejected: {code} \
                                        ({message})");
                            }
                        }
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().expect("client thread");
        }

        let mut cl = Client::connect(addr).expect("connect for shutdown");
        // one wire trace snapshot per run: tracing is on (see main), so
        // the served load must have left events and gauges behind
        let snap = cl.trace().expect("trace snapshot");
        assert_eq!(snap.str_or("type", ""), "trace");
        assert!(snap.bool_or("enabled", false), "bench enables tracing");
        assert!(snap.get("events").and_then(|e| e.as_arr())
                    .map(|a| !a.is_empty()).unwrap_or(false),
                "traced serving left no events in the ring");
        cl.shutdown_server().expect("shutdown");
        srv.join().expect("server thread").expect("server run")
    })
}

/// Human label for a `prefill_chunk` setting (0 = whole prompt per
/// iteration).
fn chunk_label(prefill_chunk: usize) -> String {
    if prefill_chunk == 0 {
        "full".into()
    } else {
        format!("{prefill_chunk}")
    }
}

fn main() {
    let rt = common::runtime();
    let p = common::prepare(rt, "tiny", "llama", 7);
    // observe-only (rust/tests/trace_equiv.rs): on for the whole bench so
    // every drive() can pull a populated wire trace snapshot
    zs_svd::obs::set_enabled(true);
    let load = if fast_mode() {
        Load { clients: 2, per_client: 2, prompt_len: 8, max_new: 6 }
    } else {
        Load { clients: 4, per_client: 6, prompt_len: 16, max_new: 16 }
    };

    let mut headers = vec!["engine", "compression", "chunk"];
    headers.extend(common::PHASE_HEADERS);
    headers.extend(LATENCY_HEADERS);
    headers.extend(["ttft p50 ms", "rejected"]);
    let mut t = Table::new(
        "server throughput (TCP loopback, streaming decode)", &headers);

    let mut emit_row = |label: &str, comp: &str, chunk: usize,
                        s: &ServerStats| {
        // steady-state decode rate (decode-step sections only) next to the
        // prefill-phase rate — the same split definitions decode_throughput
        // reports.  NOT tokens over the whole wall clock, which would
        // charge connect gaps and the drain to the TCP tier
        let pre = s.counters.prefill_tok_per_sec();
        let dec = s.counters.decode_tok_per_sec();
        eprintln!("  {label}@{comp} chunk {}: {pre:.0} prefill tok/s, \
                   {dec:.0} decode tok/s over TCP",
                  chunk_label(chunk));
        let mut row = vec![label.to_string(), comp.to_string(),
                           chunk_label(chunk)];
        row.extend(common::phase_cells(pre, dec));
        row.extend(latency_cells(&s.e2e));
        row.extend([f2(s.ttft.p50), format!("{}", s.requests_rejected)]);
        t.row(row);
    };

    let d = drive(&p, &p.params, &Engine::Dense, &load, 0);
    emit_row("original", "0%", 0, &d);

    // the zs-svd engine sweeps the prefill chunk: tokens are identical at
    // every size, so the prefill tok/s column isolates the batching win
    // (1 ≈ the old token-at-a-time path, full = whole-prompt GEMMs)
    let chunk_sweep = [1usize, 4, 0];
    for (i, (comp, ratio)) in [("40%", 0.6), ("60%", 0.4)].iter().enumerate() {
        let plan = coordinator::run_method(&p, &Method::zs(*ratio), *ratio)
            .expect("compress");
        let tag = format!("{}", (ratio * 100.0) as usize);
        let lm = p.session.cfg.lowrank.get(&tag).expect("artifact tag");
        let engine = Engine::from_plan_capped(&tag, &plan, &lm.ranks);
        let params = plan.apply(&p.params);
        if i == 0 {
            for &chunk in &chunk_sweep {
                let s = drive(&p, &params, &engine, &load, chunk);
                emit_row(&plan.method, comp, chunk, &s);
            }
        } else {
            let s = drive(&p, &params, &engine, &load, 0);
            emit_row(&plan.method, comp, 0, &s);
        }
    }

    common::emit("server_throughput", &t);
}
