//! Table 3 — ZS-SVD vs structured pruning on the LLaMA-2-7B analog
//! (independently-trained tiny checkpoint, seed 8) at ratios 0.6 and 0.4.
//! Accuracy columns follow the paper: PIQA / HellaSwag / WinoGrande /
//! ARC-E / ARC-C analogs.  Remap rows at 0.6, HQ row at 0.4.

mod common;

use zs_svd::compress::baselines::PruneScore;
use zs_svd::coordinator::{self, Method};
use zs_svd::data::TaskFamily;
use zs_svd::eval;
use zs_svd::report::{acc2, Table};
use zs_svd::util::benchkit::fast_mode;

const FAMS: [TaskFamily; 5] = [TaskFamily::PiqaSyn, TaskFamily::HellasSyn,
                               TaskFamily::WinogSyn, TaskFamily::ArcESyn,
                               TaskFamily::ArcCSyn];

fn main() {
    let rt = common::runtime();
    let p = common::prepare(rt, "tiny", "llama2", 8);
    let spec = common::spec();

    let eval_subset = |params: &zs_svd::model::ParamStore| {
        eval::evaluate_subset(&p.session, params, &p.eval_corpora, &p.world,
                              &spec, &FAMS).unwrap()
    };
    let base = eval_subset(&p.params);

    let mut t = Table::new(
        "Table 3: vs structured pruning (llama2 analog)",
        &["ratio", "method", "piqa", "hellas", "winog", "arc_e", "arc_c", "avg"],
    );
    let push = |ratio: &str, label: &str, r: &eval::EvalReport, t: &mut Table| {
        let mut row = vec![ratio.to_string(), label.to_string()];
        for (_, a) in &r.acc {
            row.push(acc2(*a));
        }
        row.push(acc2(r.avg_acc()));
        t.row(row);
    };
    push("1.0", "baseline", &base, &mut t);

    let ratios: &[f64] = if fast_mode() { &[0.3] } else { &[0.3, 0.2] }; // paper 0.6/0.4 bands
    for &ratio in ratios {
        let mut methods = vec![
            Method::Prune(PruneScore::Magnitude),
            Method::SliceGpt,
            Method::Prune(PruneScore::WandaSp),
            Method::SvdLlm,
            Method::zs(ratio),
        ];
        if ratio >= 0.25 {
            methods.push(Method::DobiSimRemap { sweeps: 1 });
            methods.push(Method::zs_remap(ratio));
        } else {
            methods.push(Method::DobiSimRemap { sweeps: 1 });
            methods.push(Method::zs_hq(ratio));
        }
        if fast_mode() {
            methods.truncate(3);
        }
        for m in methods {
            let plan = coordinator::run_method(&p, &m, ratio).unwrap();
            let r = eval_subset(&plan.apply(&p.params));
            eprintln!("  ratio {ratio} {}: done", plan.method);
            push(&format!("{ratio}"), &plan.method, &r, &mut t);
        }
    }

    common::emit("table3_pruning_llama2", &t);
}
