//! L3 micro-benchmarks: the compression-time linalg hot paths (SVD,
//! Cholesky, triangular solves, matmul) at the shapes the shipped configs
//! actually hit — the profile driving the §Perf optimization pass.

mod common;

use zs_svd::linalg::{cholesky_ridge, gram, matmul, right_solve_lower, svd};
use zs_svd::report::{f2, Table};
use zs_svd::tensor::Mat;
use zs_svd::util::benchkit::Bench;
use zs_svd::util::rng::Rng;

fn main() {
    let mut rng = Rng::new(42);
    let b = Bench::default();
    let mut t = Table::new(
        "linalg micro-benchmarks (median ms)",
        &["op", "shape", "ms", "p95 ms"],
    );

    // shapes from the shipped configs: d=128/192, ff=352/512
    let shapes = [(128usize, 128usize), (352, 128), (128, 352), (512, 192)];
    for &(m, n) in &shapes {
        let a = Mat::randn(&mut rng, m, n, 1.0);
        let s = b.run(|| {
            std::hint::black_box(svd(&a));
        });
        t.row(vec!["svd".into(), format!("{m}x{n}"),
                   f2(s.median * 1e3), f2(s.p95 * 1e3)]);
    }

    for &n in &[128usize, 352, 512] {
        let x = Mat::randn(&mut rng, 2 * n, n, 1.0);
        let c = gram(&x);
        let s = b.run(|| {
            std::hint::black_box(cholesky_ridge(&c, 1e-6));
        });
        t.row(vec!["cholesky".into(), format!("{n}x{n}"),
                   f2(s.median * 1e3), f2(s.p95 * 1e3)]);

        let (l, _) = cholesky_ridge(&c, 1e-6);
        let bmat = Mat::randn(&mut rng, 64, n, 1.0);
        let s = b.run(|| {
            std::hint::black_box(right_solve_lower(&bmat, &l));
        });
        t.row(vec!["right_solve".into(), format!("64x{n}"),
                   f2(s.median * 1e3), f2(s.p95 * 1e3)]);
    }

    for &(m, k, n) in &[(352usize, 128usize, 352usize), (128, 352, 128),
                        (512, 192, 512)] {
        let a = Mat::randn(&mut rng, m, k, 1.0);
        let bb = Mat::randn(&mut rng, k, n, 1.0);
        let s = b.run(|| {
            std::hint::black_box(matmul(&a, &bb));
        });
        let flops = 2.0 * (m * k * n) as f64;
        t.row(vec![format!("matmul ({:.2} GF/s)", flops / s.median / 1e9),
                   format!("{m}x{k}x{n}"),
                   f2(s.median * 1e3), f2(s.p95 * 1e3)]);
    }

    common::emit("microbench_linalg", &t);
}
