//! L3 micro-benchmarks: the compression-time linalg hot paths (SVD,
//! Cholesky, triangular solves, matmul) at the shapes the shipped configs
//! actually hit — the profile driving the §Perf optimization pass — plus
//! the thread-scaling sweep for the `exec` parallel subsystem (parallel
//! matmul and `decompose_all` at 1/2/4 workers, with speedups vs serial)
//! and the **kernel-level GFLOP/s sweep** for the SIMD micro-kernel layer:
//! the pre-SIMD scalar kernels vs the portable lane-strided backend vs the
//! AVX2 backend, at decode-single-row through prefill-chunk shapes.  The
//! kernel sweep is written machine-readably to `BENCH_5.json` at the repo
//! root to start the perf trajectory; `PAR_MIN_MACS` in `linalg::matmul`
//! is calibrated against it.

mod common;

use zs_svd::compress::pipeline::decompose_all;
use zs_svd::compress::Calibration;
use zs_svd::exec;
use zs_svd::linalg::kernels::{self, Backend};
use zs_svd::linalg::{cholesky_ridge, dot_f32, gram, matmul, matmul_bt,
                     right_solve_lower, svd};
use zs_svd::model::init::init_params;
use zs_svd::report::{f2, Table};
use zs_svd::runtime::session::Session;
use zs_svd::runtime::Runtime;
use zs_svd::tensor::Mat;
use zs_svd::util::benchkit::{fast_mode, Bench};
use zs_svd::util::json::Json;
use zs_svd::util::rng::Rng;
use zs_svd::util::stats::Summary;

// ---------------------------------------------------------------------------
// the pre-SIMD kernels, frozen here as the GFLOP/s baseline
// ---------------------------------------------------------------------------

/// The pre-SIMD 4-lane unrolled dot (what the autovectorizer used to get).
fn legacy_dot(a: &[f32], b: &[f32]) -> f32 {
    let mut acc = [0.0f32; 4];
    let chunks = a.len() / 4;
    for c in 0..chunks {
        let i = c * 4;
        acc[0] += a[i] * b[i];
        acc[1] += a[i + 1] * b[i + 1];
        acc[2] += a[i + 2] * b[i + 2];
        acc[3] += a[i + 3] * b[i + 3];
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for i in chunks * 4..a.len() {
        s += a[i] * b[i];
    }
    s
}

/// The pre-SIMD blocked scalar GEMM (including its `aik == 0` skip branch).
fn legacy_matmul(a: &Mat, b: &Mat) -> Mat {
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let mut c = Mat::zeros(m, n);
    const BK: usize = 64;
    const BJ: usize = 256;
    for kb in (0..k).step_by(BK) {
        let kend = (kb + BK).min(k);
        for jb in (0..n).step_by(BJ) {
            let jend = (jb + BJ).min(n);
            for i in 0..m {
                let arow = &a.data[i * k..(i + 1) * k];
                let crow = &mut c.data[i * n..(i + 1) * n];
                for kk in kb..kend {
                    let aik = arow[kk];
                    if aik == 0.0 {
                        continue;
                    }
                    let brow = &b.data[kk * n..(kk + 1) * n];
                    for j in jb..jend {
                        crow[j] += aik * brow[j];
                    }
                }
            }
        }
    }
    c
}

/// The pre-SIMD A·Bᵀ (one legacy dot per output element).
fn legacy_matmul_bt(a: &Mat, b: &Mat) -> Mat {
    let (m, k, n) = (a.rows, a.cols, b.rows);
    let mut c = Mat::zeros(m, n);
    for i in 0..m {
        let arow = &a.data[i * k..(i + 1) * k];
        let crow = &mut c.data[i * n..(i + 1) * n];
        for j in 0..n {
            crow[j] = legacy_dot(arow, &b.data[j * k..(j + 1) * k]);
        }
    }
    c
}

/// One kernel-sweep measurement: table row + BENCH_5.json entry.
fn record(t: &mut Table, out: &mut Vec<Json>, kernel: &str, shape: &str,
          backend: &str, flops: f64, s: &Summary) {
    let gflops = flops / s.median.max(1e-12) / 1e9;
    t.row(vec![format!("{kernel}/{backend} ({gflops:.2} GF/s)"),
               shape.to_string(), f2(s.median * 1e3), f2(s.p95 * 1e3)]);
    out.push(Json::obj(vec![
        ("kernel", Json::str(kernel)),
        ("shape", Json::str(shape)),
        ("backend", Json::str(backend)),
        ("median_ms", Json::num(s.median * 1e3)),
        ("p95_ms", Json::num(s.p95 * 1e3)),
        ("gflops", Json::num(gflops)),
    ]));
}

fn main() {
    let mut rng = Rng::new(42);
    let b = Bench::default();
    let mut t = Table::new(
        "linalg micro-benchmarks (median ms)",
        &["op", "shape", "ms", "p95 ms"],
    );

    // single-threaded baseline numbers for the classic section
    exec::set_threads(1);

    // shapes from the shipped configs: d=128/192, ff=352/512
    let shapes = [(128usize, 128usize), (352, 128), (128, 352), (512, 192)];
    for &(m, n) in &shapes {
        let a = Mat::randn(&mut rng, m, n, 1.0);
        let s = b.run(|| {
            std::hint::black_box(svd(&a));
        });
        t.row(vec!["svd".into(), format!("{m}x{n}"),
                   f2(s.median * 1e3), f2(s.p95 * 1e3)]);
    }

    for &n in &[128usize, 352, 512] {
        let x = Mat::randn(&mut rng, 2 * n, n, 1.0);
        let c = gram(&x);
        let s = b.run(|| {
            std::hint::black_box(cholesky_ridge(&c, 1e-6));
        });
        t.row(vec!["cholesky".into(), format!("{n}x{n}"),
                   f2(s.median * 1e3), f2(s.p95 * 1e3)]);

        let (l, _) = cholesky_ridge(&c, 1e-6);
        let bmat = Mat::randn(&mut rng, 64, n, 1.0);
        let s = b.run(|| {
            std::hint::black_box(right_solve_lower(&bmat, &l));
        });
        t.row(vec!["right_solve".into(), format!("64x{n}"),
                   f2(s.median * 1e3), f2(s.p95 * 1e3)]);
    }

    for &(m, k, n) in &[(352usize, 128usize, 352usize), (128, 352, 128),
                        (512, 192, 512)] {
        let a = Mat::randn(&mut rng, m, k, 1.0);
        let bb = Mat::randn(&mut rng, k, n, 1.0);
        let s = b.run(|| {
            std::hint::black_box(matmul(&a, &bb));
        });
        let flops = 2.0 * (m * k * n) as f64;
        t.row(vec![format!("matmul ({:.2} GF/s)", flops / s.median / 1e9),
                   format!("{m}x{k}x{n}"),
                   f2(s.median * 1e3), f2(s.p95 * 1e3)]);
    }

    // ---------------------------------------------------------------
    // SIMD kernel layer: GFLOP/s per backend vs the frozen pre-SIMD
    // scalar kernels, at decode-single-row through prefill-chunk shapes.
    // Serial on purpose (exec::set_threads(1) above): this measures the
    // micro-kernels, not the pool.  BENCH_5.json is regenerated from this
    // section on every run.
    // ---------------------------------------------------------------
    let mut kernel_json: Vec<Json> = Vec::new();
    let mut backends: Vec<(&str, Backend)> =
        vec![("portable", Backend::Portable)];
    if kernels::simd_available() {
        backends.push(("avx2", Backend::Avx2));
    } else {
        eprintln!("note: no AVX2 on this host — kernel sweep records the \
                   portable backend only");
    }

    // dot products at row-reduction lengths (decode q·k, projections)
    let dot_reps = 512usize;
    for &len in &[128usize, 512, 4096] {
        let xa = Mat::randn(&mut rng, 1, len, 1.0);
        let xb = Mat::randn(&mut rng, 1, len, 1.0);
        let (va, vb) = (&xa.data, &xb.data);
        let flops = 2.0 * (len * dot_reps) as f64;
        let shape = format!("len {len}");
        let s = b.run(|| {
            let mut acc = 0.0f32;
            for _ in 0..dot_reps {
                acc += legacy_dot(std::hint::black_box(va),
                                  std::hint::black_box(vb));
            }
            std::hint::black_box(acc);
        });
        record(&mut t, &mut kernel_json, "dot", &shape, "legacy-scalar",
               flops, &s);
        for &(bname, bk) in &backends {
            kernels::force_backend(Some(bk));
            let s = b.run(|| {
                let mut acc = 0.0f32;
                for _ in 0..dot_reps {
                    acc += dot_f32(std::hint::black_box(va),
                                   std::hint::black_box(vb));
                }
                std::hint::black_box(acc);
            });
            record(&mut t, &mut kernel_json, "dot", &shape, bname, flops, &s);
            kernels::force_backend(None);
        }
    }

    // GEMMs: decode single-row, prefill chunks, compression shapes
    let gemm_shapes: &[(usize, usize, usize)] = if fast_mode() {
        &[(1, 128, 512), (16, 128, 512), (128, 352, 128)]
    } else {
        &[(1, 128, 512), (16, 128, 512), (32, 352, 352), (128, 352, 128),
          (512, 192, 512)]
    };
    for &(m, k, n) in gemm_shapes {
        let a = Mat::randn(&mut rng, m, k, 1.0);
        let bb = Mat::randn(&mut rng, k, n, 1.0);
        let btm = Mat::randn(&mut rng, n, k, 1.0);
        let flops = 2.0 * (m * k * n) as f64;
        let shape = format!("{m}x{k}x{n}");

        let s = b.run(|| {
            std::hint::black_box(legacy_matmul(&a, &bb));
        });
        record(&mut t, &mut kernel_json, "mm", &shape, "legacy-scalar",
               flops, &s);
        let s = b.run(|| {
            std::hint::black_box(legacy_matmul_bt(&a, &btm));
        });
        record(&mut t, &mut kernel_json, "mm_bt", &shape, "legacy-scalar",
               flops, &s);

        for &(bname, bk) in &backends {
            kernels::force_backend(Some(bk));
            let s = b.run(|| {
                std::hint::black_box(matmul(&a, &bb));
            });
            record(&mut t, &mut kernel_json, "mm", &shape, bname, flops, &s);
            let s = b.run(|| {
                std::hint::black_box(matmul_bt(&a, &btm));
            });
            record(&mut t, &mut kernel_json, "mm_bt", &shape, bname, flops,
                   &s);
            kernels::force_backend(None);
        }
    }

    let bench5 = Json::obj(vec![
        ("bench", Json::str("microbench_linalg/kernels")),
        ("generated_by",
         Json::str("cargo bench --bench microbench_linalg (also run by ci.sh)")),
        ("fast_mode", Json::Bool(fast_mode())),
        ("simd_available", Json::Bool(kernels::simd_available())),
        ("threads", Json::num(1.0)),
        ("units", Json::str("median_ms/p95_ms wall clock, gflops = 2·m·k·n \
                             / median; dot entries amortize 512 calls")),
        ("results", Json::Arr(kernel_json)),
    ]);
    let bench5_path = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("BENCH_5.json");
    std::fs::write(&bench5_path, bench5.to_string_pretty() + "\n")
        .expect("write BENCH_5.json");
    println!("[saved {}]", bench5_path.display());

    // ---------------------------------------------------------------
    // thread scaling: parallel matmul (row-partitioned kernel)
    // ---------------------------------------------------------------
    let (m, k, n) = (512usize, 384usize, 512usize);
    let a = Mat::randn(&mut rng, m, k, 1.0);
    let bb = Mat::randn(&mut rng, k, n, 1.0);
    let mut serial_median = 0.0f64;
    for &threads in &[1usize, 2, 4] {
        exec::set_threads(threads);
        let s = b.run(|| {
            std::hint::black_box(matmul(&a, &bb));
        });
        if threads == 1 {
            serial_median = s.median;
        }
        let speedup = serial_median / s.median.max(1e-12);
        t.row(vec![format!("matmul-par t={threads} ({speedup:.2}x)"),
                   format!("{m}x{k}x{n}"),
                   f2(s.median * 1e3), f2(s.p95 * 1e3)]);
        eprintln!("matmul {m}x{k}x{n} @ {threads} threads: {:.2} ms \
                   ({speedup:.2}x vs 1 thread)", s.median * 1e3);
    }

    // ---------------------------------------------------------------
    // thread scaling: decompose_all (per-target whitened SVD fan-out)
    // ---------------------------------------------------------------
    let rt = Runtime::load_default().expect("builtin manifest");
    let sess = Session::new(&rt, "tiny");
    let mut prng = Rng::new(7);
    let params = init_params(&sess.cfg, &mut prng);
    let calib = Calibration::synthetic(&sess.cfg, 0xCA11B, Vec::new());
    let db = Bench::new(1, if fast_mode() { 2 } else { 4 });
    let mut serial_median = 0.0f64;
    for &threads in &[1usize, 2, 4] {
        exec::set_threads(threads);
        let s = db.run(|| {
            std::hint::black_box(decompose_all(&sess, &params, &calib));
        });
        if threads == 1 {
            serial_median = s.median;
        }
        let speedup = serial_median / s.median.max(1e-12);
        t.row(vec![format!("decompose_all t={threads} ({speedup:.2}x)"),
                   format!("{} targets", sess.cfg.targets.len()),
                   f2(s.median * 1e3), f2(s.p95 * 1e3)]);
        eprintln!("decompose_all ({} targets) @ {threads} threads: {:.1} ms \
                   ({speedup:.2}x vs 1 thread)",
                  sess.cfg.targets.len(), s.median * 1e3);
    }
    exec::set_threads(0);

    common::emit("microbench_linalg", &t);
}
