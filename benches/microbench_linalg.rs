//! L3 micro-benchmarks: the compression-time linalg hot paths (SVD,
//! Cholesky, triangular solves, matmul) at the shapes the shipped configs
//! actually hit — the profile driving the §Perf optimization pass — plus
//! the thread-scaling sweep for the `exec` parallel subsystem (parallel
//! matmul and `decompose_all` at 1/2/4 workers, with speedups vs serial).

mod common;

use zs_svd::compress::pipeline::decompose_all;
use zs_svd::compress::Calibration;
use zs_svd::exec;
use zs_svd::linalg::{cholesky_ridge, gram, matmul, right_solve_lower, svd};
use zs_svd::model::init::init_params;
use zs_svd::report::{f2, Table};
use zs_svd::runtime::session::Session;
use zs_svd::runtime::Runtime;
use zs_svd::tensor::Mat;
use zs_svd::util::benchkit::{fast_mode, Bench};
use zs_svd::util::rng::Rng;

fn main() {
    let mut rng = Rng::new(42);
    let b = Bench::default();
    let mut t = Table::new(
        "linalg micro-benchmarks (median ms)",
        &["op", "shape", "ms", "p95 ms"],
    );

    // single-threaded baseline numbers for the classic section
    exec::set_threads(1);

    // shapes from the shipped configs: d=128/192, ff=352/512
    let shapes = [(128usize, 128usize), (352, 128), (128, 352), (512, 192)];
    for &(m, n) in &shapes {
        let a = Mat::randn(&mut rng, m, n, 1.0);
        let s = b.run(|| {
            std::hint::black_box(svd(&a));
        });
        t.row(vec!["svd".into(), format!("{m}x{n}"),
                   f2(s.median * 1e3), f2(s.p95 * 1e3)]);
    }

    for &n in &[128usize, 352, 512] {
        let x = Mat::randn(&mut rng, 2 * n, n, 1.0);
        let c = gram(&x);
        let s = b.run(|| {
            std::hint::black_box(cholesky_ridge(&c, 1e-6));
        });
        t.row(vec!["cholesky".into(), format!("{n}x{n}"),
                   f2(s.median * 1e3), f2(s.p95 * 1e3)]);

        let (l, _) = cholesky_ridge(&c, 1e-6);
        let bmat = Mat::randn(&mut rng, 64, n, 1.0);
        let s = b.run(|| {
            std::hint::black_box(right_solve_lower(&bmat, &l));
        });
        t.row(vec!["right_solve".into(), format!("64x{n}"),
                   f2(s.median * 1e3), f2(s.p95 * 1e3)]);
    }

    for &(m, k, n) in &[(352usize, 128usize, 352usize), (128, 352, 128),
                        (512, 192, 512)] {
        let a = Mat::randn(&mut rng, m, k, 1.0);
        let bb = Mat::randn(&mut rng, k, n, 1.0);
        let s = b.run(|| {
            std::hint::black_box(matmul(&a, &bb));
        });
        let flops = 2.0 * (m * k * n) as f64;
        t.row(vec![format!("matmul ({:.2} GF/s)", flops / s.median / 1e9),
                   format!("{m}x{k}x{n}"),
                   f2(s.median * 1e3), f2(s.p95 * 1e3)]);
    }

    // ---------------------------------------------------------------
    // thread scaling: parallel matmul (row-partitioned kernel)
    // ---------------------------------------------------------------
    let (m, k, n) = (512usize, 384usize, 512usize);
    let a = Mat::randn(&mut rng, m, k, 1.0);
    let bb = Mat::randn(&mut rng, k, n, 1.0);
    let mut serial_median = 0.0f64;
    for &threads in &[1usize, 2, 4] {
        exec::set_threads(threads);
        let s = b.run(|| {
            std::hint::black_box(matmul(&a, &bb));
        });
        if threads == 1 {
            serial_median = s.median;
        }
        let speedup = serial_median / s.median.max(1e-12);
        t.row(vec![format!("matmul-par t={threads} ({speedup:.2}x)"),
                   format!("{m}x{k}x{n}"),
                   f2(s.median * 1e3), f2(s.p95 * 1e3)]);
        eprintln!("matmul {m}x{k}x{n} @ {threads} threads: {:.2} ms \
                   ({speedup:.2}x vs 1 thread)", s.median * 1e3);
    }

    // ---------------------------------------------------------------
    // thread scaling: decompose_all (per-target whitened SVD fan-out)
    // ---------------------------------------------------------------
    let rt = Runtime::load_default().expect("builtin manifest");
    let sess = Session::new(&rt, "tiny");
    let mut prng = Rng::new(7);
    let params = init_params(&sess.cfg, &mut prng);
    let calib = Calibration::synthetic(&sess.cfg, 0xCA11B, Vec::new());
    let db = Bench::new(1, if fast_mode() { 2 } else { 4 });
    let mut serial_median = 0.0f64;
    for &threads in &[1usize, 2, 4] {
        exec::set_threads(threads);
        let s = db.run(|| {
            std::hint::black_box(decompose_all(&sess, &params, &calib));
        });
        if threads == 1 {
            serial_median = s.median;
        }
        let speedup = serial_median / s.median.max(1e-12);
        t.row(vec![format!("decompose_all t={threads} ({speedup:.2}x)"),
                   format!("{} targets", sess.cfg.targets.len()),
                   f2(s.median * 1e3), f2(s.p95 * 1e3)]);
        eprintln!("decompose_all ({} targets) @ {threads} threads: {:.1} ms \
                   ({speedup:.2}x vs 1 thread)",
                  sess.cfg.targets.len(), s.median * 1e3);
    }
    exec::set_threads(0);

    common::emit("microbench_linalg", &t);
}
