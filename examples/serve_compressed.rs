//! Serving demo: batched prefill requests against the dense model and the
//! ZS-SVD-compressed model running through the fused Pallas low-rank
//! artifacts, reporting throughput, latency percentiles and memory.
//!
//!     cargo run --release --example serve_compressed [ratio] [requests]

use anyhow::Result;

use zs_svd::config::ExperimentConfig;
use zs_svd::coordinator::{self, Method};
use zs_svd::report::{f2, Table};
use zs_svd::runtime::Runtime;
use zs_svd::serve::{run_serving, Engine, ServeConfig};

fn main() -> Result<()> {
    let ratio: f64 = std::env::args().nth(1)
        .and_then(|s| s.parse().ok()).unwrap_or(0.6);
    let requests: usize = std::env::args().nth(2)
        .and_then(|s| s.parse().ok()).unwrap_or(48);

    let rt = Runtime::load_default()?;
    let cfg = ExperimentConfig::default();
    let p = coordinator::prepare(&rt, &cfg)?;

    println!("compressing at retention {ratio} for low-rank serving...");
    let plan = coordinator::run_method(&p, &Method::zs(ratio), ratio)?;
    println!("  achieved ratio {:.3}, {}", plan.achieved_ratio(),
             coordinator::rank_summary(&plan));

    let sc = ServeConfig { n_requests: requests, ..Default::default() };
    let dense_bytes = p.session.cfg.param_count() as f64 * 2.0;

    println!("serving {requests} prefill requests (batch {})...", sc.max_batch);
    let d = run_serving(&p.session, &p.params, &Engine::Dense, &sc, dense_bytes)?;
    let tag = format!("{}", (ratio * 100.0) as usize);
    let lm = p.session.cfg.lowrank.get(&tag).expect("lowrank artifact");
    let engine = Engine::from_plan_capped(&tag, &plan, &lm.ranks);
    let compressed_params = plan.apply(&p.params);
    let l = run_serving(&p.session, &compressed_params, &engine, &sc,
                        plan.model_bytes(&p.session.cfg))?;

    let mut t = Table::new(
        &format!("serving tiny @ {}% compression", ((1.0 - ratio) * 100.0) as usize),
        &["engine", "tok/s", "p50 ms", "p95 ms", "p99 ms", "weights MB",
          "act MB", "peak RSS MB"],
    );
    for s in [&d, &l] {
        t.row(vec![s.engine.clone(), f2(s.tokens_per_sec), f2(s.latency.p50),
                   f2(s.latency.p95), f2(s.latency.p99),
                   f2(s.weight_mem_bytes / 1e6),
                   f2(s.act_mem_bytes as f64 / 1e6),
                   f2(s.peak_mem_bytes as f64 / 1e6)]);
    }
    print!("{}", t.to_ascii());
    println!("speedup (low-rank / dense): {:.2}x",
             l.tokens_per_sec / d.tokens_per_sec);
    Ok(())
}
