//! End-to-end driver (DESIGN.md §End-to-end validation): train a transformer
//! from scratch through the AOT train-step executable, log the loss curve,
//! then compress with ZS-SVD vs SVD-LLM at three ratios and evaluate
//! perplexity + zero-shot accuracy.  The printed output is the source of the
//! E2E record in EXPERIMENTS.md.
//!
//!     cargo run --release --example train_and_compress [steps]

use anyhow::Result;

use zs_svd::compress::calibrate;
use zs_svd::config::ExperimentConfig;
use zs_svd::coordinator::{self, Method, Prepared};
use zs_svd::data;
use zs_svd::eval::EvalSpec;
use zs_svd::report::{acc2, f2, pct, Table};
use zs_svd::runtime::session::Session;
use zs_svd::runtime::Runtime;
use zs_svd::trainer::{train, TrainConfig};

fn main() -> Result<()> {
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(300);
    let rt = Runtime::load_default()?;
    let session = Session::new(&rt, "tiny");
    let world = data::default_world();
    let train_corpus = data::training_corpus("llama", &world);
    let eval_corpora = data::eval_corpora(&world);

    // ---- phase 1: pretrain from scratch, log the loss curve ----
    println!("== phase 1: training tiny ({} params) for {steps} steps ==",
             session.cfg.param_count());
    let tc = TrainConfig { steps, lr: 3e-3, warmup: steps / 10, seed: 7,
                           log_every: 20 };
    let t0 = std::time::Instant::now();
    let result = train(&session, &train_corpus, &tc, false)?;
    let dt = t0.elapsed().as_secs_f64();
    let tokens = steps * session.cfg.batch * session.cfg.seq_len;
    println!("trained in {dt:.1}s  ({:.0} tok/s)", tokens as f64 / dt);
    println!("loss curve (every {}th):", (steps / 15).max(1));
    for (i, l) in result.losses.iter().enumerate() {
        if i % (steps / 15).max(1) == 0 || i + 1 == steps {
            println!("  step {i:4}  loss {l:.4}");
        }
    }
    anyhow::ensure!(
        *result.losses.last().unwrap() < result.losses[0] - 2.0,
        "training did not converge"
    );

    // ---- phase 2: calibrate + compress + evaluate ----
    println!("\n== phase 2: compress + evaluate ==");
    let cfg = ExperimentConfig::default();
    let calib = calibrate(&session, &result.params, &train_corpus, 8, 0xCA11B)?;
    let p = Prepared { session, params: result.params, world,
                       train_corpus, eval_corpora, calib };
    let spec = EvalSpec { ppl_batches: cfg.ppl_batches,
                          instances_per_family: cfg.instances_per_family,
                          task_seed: 0xE1 };
    let dense = coordinator::evaluate_plan(&p, None, &spec)?;

    let mut t = Table::new(
        "E2E: train -> compress -> evaluate (tiny)",
        &["ratio", "method", "ppl(wiki)", "ppl(ptb)", "ppl(c4)", "acc", "drop%"],
    );
    t.row(vec!["1.0".into(), "dense".into(), f2(dense.ppl_of("wiki-syn")),
               f2(dense.ppl_of("ptb-syn")), f2(dense.ppl_of("c4-syn")),
               acc2(dense.avg_acc()), "0.0".into()]);
    for ratio in [0.8, 0.6, 0.4] {
        for m in [Method::SvdLlm, Method::zs(ratio)] {
            let plan = coordinator::run_method(&p, &m, ratio)?;
            let r = coordinator::evaluate_plan(&p, Some(&plan), &spec)?;
            t.row(vec![format!("{ratio}"), plan.method.clone(),
                       f2(r.ppl_of("wiki-syn")), f2(r.ppl_of("ptb-syn")),
                       f2(r.ppl_of("c4-syn")), acc2(r.avg_acc()),
                       pct(r.drop_vs(&dense))]);
        }
    }
    print!("{}", t.to_ascii());
    println!("\n(record this output in EXPERIMENTS.md §End-to-end)");
    Ok(())
}
