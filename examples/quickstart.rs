//! Quickstart: load (or quickly pretrain) the tiny model, compress it with
//! ZS-SVD at 60% retention, and compare perplexity/accuracy before/after.
//!
//!     make artifacts && cargo run --release --example quickstart

use anyhow::Result;

use zs_svd::config::ExperimentConfig;
use zs_svd::coordinator::{self, Method};
use zs_svd::eval::EvalSpec;
use zs_svd::report::{acc2, f2, pct, Table};
use zs_svd::runtime::Runtime;

fn main() -> Result<()> {
    let rt = Runtime::load_default()?;
    let cfg = ExperimentConfig::default();

    println!("preparing model `{}` (cached checkpoint or ~80 s pretrain)...",
             cfg.model);
    let p = coordinator::prepare(&rt, &cfg)?;

    let spec = EvalSpec { ppl_batches: 4, instances_per_family: 32, task_seed: 0xE1 };
    let dense = coordinator::evaluate_plan(&p, None, &spec)?;

    let ratio = 0.6;
    println!("compressing with ZS-SVD at retention {ratio} ...");
    let plan = coordinator::run_method(&p, &Method::zs(ratio), ratio)?;
    println!("  {} in {:.2}s, achieved ratio {:.3}, {}",
             plan.method, plan.seconds, plan.achieved_ratio(),
             coordinator::rank_summary(&plan));

    let compressed = coordinator::evaluate_plan(&p, Some(&plan), &spec)?;

    let mut t = Table::new("quickstart: ZS-SVD @ 0.6 on tiny",
                           &["metric", "dense", "zs-svd"]);
    for ((n, d), (_, c)) in dense.ppl.iter().zip(&compressed.ppl) {
        t.row(vec![format!("ppl/{n}"), f2(*d), f2(*c)]);
    }
    t.row(vec!["acc avg".into(), acc2(dense.avg_acc()),
               acc2(compressed.avg_acc())]);
    t.row(vec!["drop %".into(), "0.0".into(), pct(compressed.drop_vs(&dense))]);
    print!("{}", t.to_ascii());
    Ok(())
}
