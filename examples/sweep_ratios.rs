//! Ratio sweep: ZS-SVD vs the SVD baselines across the retention grid,
//! tracing the perplexity/accuracy frontier (the qualitative shape of the
//! paper's Table 1).
//!
//!     cargo run --release --example sweep_ratios

use anyhow::Result;

use zs_svd::config::ExperimentConfig;
use zs_svd::coordinator::{self, Method};
use zs_svd::eval::EvalSpec;
use zs_svd::report::{acc2, f2, pct, Table};
use zs_svd::runtime::Runtime;

fn main() -> Result<()> {
    let rt = Runtime::load_default()?;
    let cfg = ExperimentConfig::default();
    let p = coordinator::prepare(&rt, &cfg)?;
    let spec = EvalSpec { ppl_batches: 4, instances_per_family: 32, task_seed: 0xE1 };
    let dense = coordinator::evaluate_plan(&p, None, &spec)?;

    let mut t = Table::new("retention sweep on tiny",
                           &["ratio", "method", "ppl(wiki)", "acc", "drop%"]);
    t.row(vec!["1.0".into(), "dense".into(), f2(dense.ppl_of("wiki-syn")),
               acc2(dense.avg_acc()), "0.0".into()]);
    for ratio in [0.9, 0.8, 0.7, 0.6, 0.5, 0.4] {
        for m in [Method::Svd, Method::Asvd, Method::SvdLlm, Method::zs(ratio)] {
            let plan = coordinator::run_method(&p, &m, ratio)?;
            let r = coordinator::evaluate_plan(&p, Some(&plan), &spec)?;
            t.row(vec![format!("{ratio}"), plan.method.clone(),
                       f2(r.ppl_of("wiki-syn")), acc2(r.avg_acc()),
                       pct(r.drop_vs(&dense))]);
        }
        println!("ratio {ratio} done");
    }
    print!("{}", t.to_ascii());
    Ok(())
}
