"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes/dtypes; numpy.testing.assert_allclose against ref.py
is the acceptance criterion (system contract for this repo).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import lowrank, attention, ref

jax.config.update("jax_enable_x64", False)


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


# ---------------------------------------------------------------------------
# low-rank linear kernel
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    rows=st.sampled_from([8, 33, 64, 128, 1024]),
    n=st.sampled_from([16, 96, 128, 352]),
    m=st.sampled_from([16, 128, 352]),
    k=st.integers(min_value=1, max_value=96),
    block_rows=st.sampled_from([8, 32, 64, 100]),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
)
def test_lowrank_matches_ref(rows, n, m, k, block_rows, dtype):
    kk = min(k, min(m, n))
    key = jax.random.PRNGKey(rows * 31 + n * 7 + m * 3 + kk)
    k1, k2, k3 = jax.random.split(key, 3)
    x = _rand(k1, (rows, n), dtype)
    wu = _rand(k2, (m, kk), dtype)
    wv = _rand(k3, (kk, n), dtype)
    got = lowrank.lowrank_linear(x, wu, wv, block_rows=block_rows)
    want = ref.lowrank_linear_ref(x, wu, wv)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol * 10)


def test_lowrank_3d_shape():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (2, 16, 24))
    wu = jax.random.normal(key, (40, 5))
    wv = jax.random.normal(key, (5, 24))
    y = lowrank.lowrank_linear_3d(x, wu, wv)
    assert y.shape == (2, 16, 40)


def test_lowrank_zero_rank_component():
    """Zeroed factor rows/cols contribute nothing — padding is sound."""
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (32, 16))
    wu = jax.random.normal(key, (24, 8))
    wv = jax.random.normal(key, (8, 16))
    base = lowrank.lowrank_linear(x, wu, wv)
    wu_pad = jnp.concatenate([wu, jnp.zeros((24, 4))], axis=1)
    wv_pad = jnp.concatenate([wv, jnp.zeros((4, 16))], axis=0)
    padded = lowrank.lowrank_linear(x, wu_pad, wv_pad)
    np.testing.assert_allclose(np.asarray(base), np.asarray(padded),
                               rtol=1e-5, atol=1e-5)


def test_lowrank_block_rows_invariance():
    key = jax.random.PRNGKey(2)
    x = jax.random.normal(key, (96, 32))
    wu = jax.random.normal(key, (48, 12))
    wv = jax.random.normal(key, (12, 32))
    outs = [lowrank.lowrank_linear(x, wu, wv, block_rows=b)
            for b in (8, 16, 48, 96)]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   rtol=1e-6, atol=1e-6)


def test_vmem_footprint_under_budget():
    """Every config/ratio this repo ships stays under the 2 MiB VMEM target."""
    from compile.configs import CONFIGS, target_spec, lowrank_rank
    for cfg in CONFIGS.values():
        for ratio in cfg.lowrank_ratios or (0.6,):
            for _, (m, n), _ in target_spec(cfg):
                k = lowrank_rank(ratio, m, n)
                fp = lowrank.vmem_footprint_bytes(64, n, m, k)
                assert fp < 2 * 1024 * 1024, (cfg.name, ratio, m, n, k, fp)


def test_flops_accounting():
    assert lowrank.flops_per_row(128, 128, 32) == 2 * 32 * 256
    # saving factor mn/(k(m+n)) at the closed-form rank ~ 1/ratio
    from compile.configs import lowrank_rank
    m = n = 128
    k = lowrank_rank(0.5, m, n)
    saving = (m * n) / (k * (m + n))
    assert 1.9 < saving < 2.2


# ---------------------------------------------------------------------------
# attention kernel
# ---------------------------------------------------------------------------

@settings(max_examples=12, deadline=None)
@given(
    bh=st.sampled_from([1, 3, 8]),
    t=st.sampled_from([16, 64, 128]),
    dh=st.sampled_from([8, 32]),
    block_q=st.sampled_from([8, 16, 32]),
)
def test_attention_matches_ref(bh, t, dh, block_q):
    key = jax.random.PRNGKey(bh * 131 + t * 3 + dh)
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (bh, t, dh))
    k = jax.random.normal(k2, (bh, t, dh))
    v = jax.random.normal(k3, (bh, t, dh))
    got = attention.mha_causal(q, k, v, block_q=block_q)
    want = ref.mha_causal_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_attention_causality():
    """Future tokens must not influence earlier outputs."""
    key = jax.random.PRNGKey(7)
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (2, 32, 8))
    k = jax.random.normal(k2, (2, 32, 8))
    v = jax.random.normal(k3, (2, 32, 8))
    out_full = attention.mha_causal(q, k, v, block_q=8)
    # perturb the last 16 positions of k/v; first 16 outputs must not move
    k2b = k.at[:, 16:].add(100.0)
    v2b = v.at[:, 16:].add(-50.0)
    out_pert = attention.mha_causal(q, k2b, v2b, block_q=8)
    np.testing.assert_allclose(np.asarray(out_full[:, :16]),
                               np.asarray(out_pert[:, :16]),
                               rtol=1e-5, atol=1e-5)


def test_attention_4d_wrapper():
    key = jax.random.PRNGKey(9)
    q = jax.random.normal(key, (2, 4, 16, 8))
    out = attention.mha_causal_4d(q, q, q, block_q=8)
    assert out.shape == (2, 4, 16, 8)
