"""L2 model tests: shapes, loss sanity, grads, moments, train step, low-rank
forward equivalence (dense weight vs its exact full-rank factorization)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.configs import CONFIGS, ModelConfig, param_spec, target_spec, \
    site_spec, lowrank_rank
from compile import model as M

TEST_CFG = ModelConfig(name="test", arch="llama", vocab=64, d_model=32,
                       n_layers=2, n_heads=2, d_ff=48, seq_len=16, batch=2)
TEST_OPT = ModelConfig(name="test_opt", arch="opt", vocab=64, d_model=32,
                       n_layers=2, n_heads=2, d_ff=64, seq_len=16, batch=2)


def _toks(cfg, key):
    return jax.random.randint(key, (cfg.batch, cfg.seq_len + 1), 0, cfg.vocab)


@pytest.mark.parametrize("cfg", [TEST_CFG, TEST_OPT], ids=["llama", "opt"])
def test_forward_shapes(cfg):
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    toks = _toks(cfg, key)
    loss, logits = M.loss_fn(cfg, params, toks)
    assert logits.shape == (cfg.batch, cfg.seq_len, cfg.vocab)
    assert np.isfinite(float(loss))
    # fresh init => loss near ln(vocab)
    assert abs(float(loss) - np.log(cfg.vocab)) < 0.5


@pytest.mark.parametrize("cfg", [TEST_CFG, TEST_OPT], ids=["llama", "opt"])
def test_param_spec_covers_params(cfg):
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    spec = param_spec(cfg)
    assert set(params) == {n for n, _ in spec}
    for n, s in spec:
        assert params[n].shape == tuple(s)


def test_target_sites_consistent():
    for cfg in [TEST_CFG, TEST_OPT]:
        sites = dict(site_spec(cfg))
        for name, (m, n), site in target_spec(cfg):
            assert site in sites
            assert sites[site] == n, (name, site)


def test_grads_entry_point():
    cfg = TEST_CFG
    key = jax.random.PRNGKey(1)
    params = M.init_params(cfg, key)
    toks = _toks(cfg, key)
    names = [n for n, _ in param_spec(cfg)]
    f = M.make_grads(cfg)
    outs = f(*[params[n] for n in names], toks)
    tspec = target_spec(cfg)
    assert len(outs) == 1 + len(tspec)
    for g, (n, s, _) in zip(outs[1:], tspec):
        assert g.shape == tuple(s)
        assert np.all(np.isfinite(np.asarray(g)))
        assert float(jnp.abs(g).max()) > 0.0  # grads actually flow


def test_moments_psd_and_counts():
    cfg = TEST_CFG
    key = jax.random.PRNGKey(2)
    params = M.init_params(cfg, key)
    toks = _toks(cfg, key)
    names = [n for n, _ in param_spec(cfg)]
    f = M.make_moments(cfg)
    outs = f(*[params[n] for n in names], toks)
    sspec = site_spec(cfg)
    assert len(outs) == 1 + 3 * len(sspec)
    assert np.isfinite(float(outs[0]))  # anchoring loss
    outs = outs[1:]
    for i, (s, n) in enumerate(sspec):
        C = np.asarray(outs[3 * i])
        assert C.shape == (n, n)
        np.testing.assert_allclose(C, C.T, rtol=1e-5, atol=1e-5)
        ev = np.linalg.eigvalsh(C)
        assert ev.min() > -1e-3  # PSD up to fp error
        abssum = np.asarray(outs[3 * i + 2])
        assert (abssum >= 0).all()


def test_train_step_reduces_loss():
    cfg = TEST_CFG
    key = jax.random.PRNGKey(3)
    params = M.init_params(cfg, key)
    toks = _toks(cfg, key)
    names = [n for n, _ in param_spec(cfg)]
    P = len(names)
    f = jax.jit(M.make_train_step(cfg))
    p = [params[n] for n in names]
    m = [jnp.zeros_like(x) for x in p]
    v = [jnp.zeros_like(x) for x in p]
    losses = []
    for step in range(8):
        outs = f(*p, *m, *v, jnp.int32(step), jnp.float32(1e-2), toks)
        p = list(outs[:P])
        m = list(outs[P:2 * P])
        v = list(outs[2 * P:3 * P])
        losses.append(float(outs[-1]))
    # memorizing a single batch must drive the loss down hard
    assert losses[-1] < losses[0] - 0.5, losses


def test_lowrank_fullrank_equivalence():
    """Factoring W exactly (full SVD, k=min(m,n)) through the pallas kernel
    must reproduce the dense forward — the L1/L2 composition contract."""
    cfg = TEST_CFG
    key = jax.random.PRNGKey(4)
    params = M.init_params(cfg, key)
    toks = _toks(cfg, key)
    lowrank = {}
    for name, (mm, nn), _ in target_spec(cfg):
        W = np.asarray(params[name])
        U, S, Vt = np.linalg.svd(W, full_matrices=False)
        half = np.sqrt(S)
        lowrank[name] = (jnp.asarray(U * half[None, :]),
                         jnp.asarray(half[:, None] * Vt))
    loss_d, logits_d = M.loss_fn(cfg, params, toks)
    loss_l, logits_l = M.loss_fn(cfg, params, toks, lowrank=lowrank)
    np.testing.assert_allclose(float(loss_d), float(loss_l),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(logits_d), np.asarray(logits_l),
                               rtol=5e-3, atol=5e-3)


def test_fwd_lowrank_entry_point():
    cfg = TEST_CFG
    ratio = 0.5
    key = jax.random.PRNGKey(5)
    params = M.init_params(cfg, key)
    toks = _toks(cfg, key)
    base, facts = M.lowrank_io_spec(cfg, ratio)
    args = [params[n] for n, _ in base]
    for n, s in facts:
        key, sub = jax.random.split(key)
        args.append(0.05 * jax.random.normal(sub, s))
    f = M.make_fwd_lowrank(cfg, ratio)
    loss, logits = f(*args, toks)
    assert logits.shape == (cfg.batch, cfg.seq_len, cfg.vocab)
    assert np.isfinite(float(loss))


def test_lowrank_rank_formula():
    assert lowrank_rank(1.0, 128, 128) == 64
    assert lowrank_rank(0.5, 128, 128) == 32
    assert lowrank_rank(0.001, 128, 128) == 1  # clamps at 1
    # paper's rho=1 saturation point: k = mn/(m+n) < min(m,n)
    assert lowrank_rank(1.0, 352, 128) == int(352 * 128 / 480)


def test_rope_orthogonality():
    """RoPE is a rotation: norms are preserved position-wise."""
    key = jax.random.PRNGKey(6)
    x = jax.random.normal(key, (1, 2, 8, 16))
    r = M.rope(x, 10000.0)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(x), axis=-1),
                               np.linalg.norm(np.asarray(r), axis=-1),
                               rtol=1e-5)


def test_shipped_configs_are_valid():
    for cfg in CONFIGS.values():
        assert cfg.d_model % cfg.n_heads == 0
        assert cfg.d_head % 2 == 0  # rope needs even head dim
        names = [n for n, _ in param_spec(cfg)]
        assert len(names) == len(set(names))
