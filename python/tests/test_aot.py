"""AOT pipeline tests: HLO text lowering sanity + manifest ABI integrity."""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot, model as M
from compile.configs import ModelConfig, param_spec, target_spec, site_spec

TEST_CFG = ModelConfig(name="test", arch="llama", vocab=64, d_model=32,
                       n_layers=2, n_heads=2, d_ff=48, seq_len=16, batch=2,
                       lowrank_ratios=(0.5,))


def test_hlo_text_roundtrip_marker(tmp_path):
    """The lowered module must be HLO text (parsable header), never a proto."""
    pspec = param_spec(TEST_CFG)
    in_ent = ([(n, s, "f32") for n, s in pspec]
              + [("tokens_io", (2, 17), "i32")])
    rec = aot.lower_artifact(
        M.make_fwd_loss(TEST_CFG), in_ent,
        [("loss", (), "f32"), ("logits", (2, 16, 64), "f32")],
        str(tmp_path / "t.hlo.txt"))
    text = (tmp_path / "t.hlo.txt").read_text()
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # one parameter per declared input
    assert text.count("parameter(") >= len(in_ent)
    assert rec["sha256"]


def test_build_config_manifest_schema(tmp_path):
    rec = aot.build_config(TEST_CFG, str(tmp_path))
    assert rec["arch"] == "llama"
    names = [p["name"] for p in rec["params"]]
    assert names == [n for n, _ in param_spec(TEST_CFG)]
    tnames = [t["name"] for t in rec["targets"]]
    assert tnames == [n for n, _, _ in target_spec(TEST_CFG)]
    for t in rec["targets"]:
        assert t["site"] in {s["name"] for s in rec["sites"]}
    for key in ("fwd", "grads", "moments", "train"):
        art = rec["artifacts"][key]
        assert os.path.exists(tmp_path / art["file"])
        assert art["inputs"] and art["outputs"]
    lr = rec["artifacts"]["lowrank"]["50"]
    assert set(lr["ranks"]) == set(tnames)
    # manifest must be valid json end-to-end
    json.dumps(rec)


def test_train_and_fwd_signatures_align(tmp_path):
    """train outputs[0:P] must have identical shapes to fwd inputs[0:P] —
    the rust trainer feeds one into the other."""
    rec = aot.build_config(TEST_CFG, str(tmp_path))
    fwd_in = rec["artifacts"]["fwd"]["inputs"]
    train_out = rec["artifacts"]["train"]["outputs"]
    P = len(rec["params"])
    for a, b in zip(fwd_in[:P], train_out[:P]):
        assert a["shape"] == b["shape"] and a["dtype"] == b["dtype"]


def test_lowering_is_deterministic(tmp_path):
    pspec = param_spec(TEST_CFG)
    in_ent = ([(n, s, "f32") for n, s in pspec]
              + [("tokens_io", (2, 17), "i32")])
    out_ent = [("loss", (), "f32"), ("logits", (2, 16, 64), "f32")]
    r1 = aot.lower_artifact(M.make_fwd_loss(TEST_CFG), in_ent, out_ent,
                            str(tmp_path / "a.hlo.txt"))
    r2 = aot.lower_artifact(M.make_fwd_loss(TEST_CFG), in_ent, out_ent,
                            str(tmp_path / "b.hlo.txt"))
    assert r1["sha256"] == r2["sha256"]
