"""Model configurations for the ZS-SVD reproduction.

Each config is an architecture the paper's experiments map onto (DESIGN.md §2):

* ``tiny``     — LLaMA-7B analog   (LLaMA-style: RMSNorm, RoPE, SwiGLU, tied embed)
* ``small``    — LLaMA-13B / LLaMA-30B analog (same arch, larger)
* ``opt_tiny`` — OPT-6.7B analog   (learned positions, LayerNorm, GELU MLP)

The "Vicuna-7B" analog reuses the ``tiny`` architecture with a different
training corpus mix, so it needs no extra HLO artifacts (weights are runtime
inputs to every executable).
"""

from dataclasses import dataclass, field


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch: str  # "llama" | "opt"
    vocab: int = 256
    d_model: int = 128
    n_layers: int = 4
    n_heads: int = 4
    d_ff: int = 352
    seq_len: int = 128
    batch: int = 8
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    # ratios for which a low-rank (pallas-kernel) forward artifact is emitted
    lowrank_ratios: tuple = (0.8, 0.6, 0.4, 0.2)

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads


CONFIGS = {
    "tiny": ModelConfig(name="tiny", arch="llama", d_model=128, n_layers=4,
                        n_heads=4, d_ff=352),
    "small": ModelConfig(name="small", arch="llama", d_model=192, n_layers=6,
                         n_heads=6, d_ff=512, lowrank_ratios=()),
    "opt_tiny": ModelConfig(name="opt_tiny", arch="opt", d_model=128,
                            n_layers=4, n_heads=4, d_ff=512,
                            lowrank_ratios=()),
}


def param_spec(cfg: ModelConfig):
    """Canonical ordered list of (name, shape) for a config's parameters.

    This ordering is the ABI between the python (build) side and the rust
    (runtime) side: every artifact takes/returns parameters in exactly this
    order, and artifacts/manifest.json records it.
    """
    d, ff, v = cfg.d_model, cfg.d_ff, cfg.vocab
    spec = [("embed", (v, d))]
    if cfg.arch == "opt":
        spec.append(("pos_embed", (cfg.seq_len, d)))
    for i in range(cfg.n_layers):
        p = f"layers.{i}."
        spec.append((p + "ln1", (d,)))
        spec.append((p + "wq", (d, d)))
        spec.append((p + "wk", (d, d)))
        spec.append((p + "wv", (d, d)))
        spec.append((p + "wo", (d, d)))
        spec.append((p + "ln2", (d,)))
        if cfg.arch == "llama":
            spec.append((p + "wgate", (ff, d)))
            spec.append((p + "wup", (ff, d)))
            spec.append((p + "wdown", (d, ff)))
        else:
            spec.append((p + "win", (ff, d)))
            spec.append((p + "wout", (d, ff)))
    spec.append(("final_ln", (d,)))
    return spec


def target_spec(cfg: ModelConfig):
    """Ordered list of (name, shape, whitening_site) for compression targets.

    Following the paper we truncate only the main transformer linear
    matrices: attention projections (q,k,v,o) and the MLP matrices.
    q/k/v share the ``attn_in`` whitening site, gate/up share ``mlp_in`` —
    the same input-sharing SVD-LLM uses.
    """
    d, ff = cfg.d_model, cfg.d_ff
    out = []
    for i in range(cfg.n_layers):
        p = f"layers.{i}."
        out.append((p + "wq", (d, d), p + "attn_in"))
        out.append((p + "wk", (d, d), p + "attn_in"))
        out.append((p + "wv", (d, d), p + "attn_in"))
        out.append((p + "wo", (d, d), p + "attn_out_in"))
        if cfg.arch == "llama":
            out.append((p + "wgate", (ff, d), p + "mlp_in"))
            out.append((p + "wup", (ff, d), p + "mlp_in"))
            out.append((p + "wdown", (d, ff), p + "mlp_down_in"))
        else:
            out.append((p + "win", (ff, d), p + "mlp_in"))
            out.append((p + "wout", (d, ff), p + "mlp_down_in"))
    return out


def site_spec(cfg: ModelConfig):
    """Ordered list of (site_name, dim) whitening sites."""
    d, ff = cfg.d_model, cfg.d_ff
    out = []
    for i in range(cfg.n_layers):
        p = f"layers.{i}."
        out.append((p + "attn_in", d))
        out.append((p + "attn_out_in", d))
        out.append((p + "mlp_in", d))
        out.append((p + "mlp_down_in", ff))
    return out


def lowrank_rank(ratio: float, m: int, n: int) -> int:
    """Closed-form uniform rank for a parameter ratio: k = floor(rho*mn/(m+n)).

    This matches SVD-LLM's homogeneous allocation; ZS-SVD's heterogeneous
    ranks are padded up to these uniform ranks for the fixed-shape serving
    artifacts (budget accounting stays exact on the rust side).
    """
    k = int(ratio * m * n / (m + n))
    return max(1, k)
