"""L1 Pallas kernels (build-time only; lowered into the AOT HLO artifacts)."""

from .lowrank import lowrank_linear, lowrank_linear_3d  # noqa: F401
from .attention import mha_causal, mha_causal_4d  # noqa: F401
from . import ref  # noqa: F401
