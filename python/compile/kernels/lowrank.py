"""L1 Pallas kernel: fused low-rank linear  y = (x @ Wv^T) @ Wu^T.

This is the serving hot-spot of every SVD-compressed layer: the dense GEMM
``y = x W^T`` (W: m x n) is replaced by two skinny GEMMs through the rank-k
bottleneck (Wv: k x n, Wu: m x k).  On GPU the paper realizes this as two
cuBLAS calls; here the two contractions are fused into ONE Pallas kernel so
the rank-k intermediate ``t = x Wv^T`` lives entirely in VMEM and never
round-trips HBM (DESIGN.md §6, Hardware Adaptation).

Tiling scheme
-------------
* grid = (rows / block_rows,) — one program per row tile of x.
* ``x`` block: (block_rows, n); ``Wv``/``Wu`` are broadcast whole (for the
  shapes this library targets, n,m <= 1k and k <= n/2, both factors fit VMEM:
  footprint = block_rows*n + k*n + m*k + block_rows*m floats; the default
  block_rows=64 keeps this well under 2 MiB for every config in
  `configs.CONFIGS`).
* both matmuls run in f32 with ``preferred_element_type=f32`` so the MXU
  accumulates at full precision even for bf16 inputs.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers the kernel to plain HLO, which is what
the AOT pipeline ships to the rust runtime.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _lowrank_kernel(x_ref, wv_ref, wu_ref, o_ref):
    # t: (block_rows, k) stays in VMEM between the two contractions.
    t = jnp.dot(x_ref[...], wv_ref[...].T, preferred_element_type=jnp.float32)
    o_ref[...] = jnp.dot(t, wu_ref[...].T,
                         preferred_element_type=jnp.float32).astype(o_ref.dtype)


def _pick_block_rows(rows: int, requested: int) -> int:
    """Largest divisor of `rows` that is <= requested (>=1)."""
    b = min(requested, rows)
    while rows % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("block_rows",))
def lowrank_linear(x, wu, wv, block_rows: int = 64):
    """y = x @ Wv^T @ Wu^T with a fused VMEM-resident rank-k intermediate.

    Args:
      x:  (rows, n) activations.
      wu: (m, k) left factor  (U_k * sqrt(Sigma_k) in the paper's Eq. 5).
      wv: (k, n) right factor (sqrt(Sigma_k) * V_k^T * S^{-1}).
      block_rows: requested row-tile size; rounded down to a divisor of rows.

    Returns:
      (rows, m) output, same dtype as x.
    """
    rows, n = x.shape
    m, k = wu.shape
    assert wv.shape == (k, n), (wv.shape, (k, n))
    br = _pick_block_rows(rows, block_rows)
    grid = (rows // br,)
    return pl.pallas_call(
        _lowrank_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, n), lambda i: (i, 0)),
            pl.BlockSpec((k, n), lambda i: (0, 0)),
            pl.BlockSpec((m, k), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((br, m), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, m), x.dtype),
        interpret=True,
    )(x, wv, wu)


def lowrank_linear_3d(x, wu, wv, block_rows: int = 64):
    """Convenience wrapper for (B, T, n) activations."""
    B, T, n = x.shape
    y = lowrank_linear(x.reshape(B * T, n), wu, wv, block_rows=block_rows)
    return y.reshape(B, T, wu.shape[0])


def vmem_footprint_bytes(rows_block: int, n: int, m: int, k: int,
                         dtype_bytes: int = 4) -> int:
    """Analytic VMEM footprint of one program instance (DESIGN.md §8)."""
    x_blk = rows_block * n
    wv = k * n
    wu = m * k
    t = rows_block * k
    out = rows_block * m
    return (x_blk + wv + wu + t + out) * dtype_bytes


def flops_per_row(m: int, n: int, k: int) -> int:
    """MACs*2 per output row: low-rank 2k(m+n) vs dense 2mn."""
    return 2 * k * (m + n)
