"""Pure-jnp oracles for the Pallas kernels.

These are the CORE correctness signal for L1: `python/tests/test_kernels.py`
sweeps shapes/dtypes with hypothesis and asserts allclose between the kernels
and these references.  They are intentionally the most naive possible
implementations.
"""

import jax.numpy as jnp


def lowrank_linear_ref(x, wu, wv):
    """y = x @ Wv^T @ Wu^T, computed as two plain matmuls in f32."""
    t = jnp.dot(x.astype(jnp.float32), wv.T.astype(jnp.float32))
    y = jnp.dot(t, wu.T.astype(jnp.float32))
    return y.astype(x.dtype)


def mha_causal_ref(q, k, v):
    """Naive causal attention over (BH, T, dh) with a full T x T score mat."""
    bh, t, dh = q.shape
    scale = 1.0 / (dh ** 0.5)
    s = jnp.einsum("btd,bsd->bts", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    mask = jnp.tril(jnp.ones((t, t), bool))
    s = jnp.where(mask[None, :, :], s, -jnp.inf)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bts,bsd->btd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
