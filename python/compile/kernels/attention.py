"""L1 Pallas kernel: causal multi-head attention with online softmax.

GPU flash-attention keeps the running softmax state (row max / normalizer /
accumulator) in warp registers and tiles K/V through shared memory.  The TPU
re-think (DESIGN.md §6): the state lives in VMEM as whole row-blocks, the
query block is the grid unit, and the K/V sweep is a `lax.fori_loop` over
lane-aligned blocks — no warp-level primitives, just MXU-shaped matmuls and
vector ops the VPU executes.

Causal structure is exploited at block granularity: the fori_loop upper bound
for query block `qi` is `qi + 1` K/V blocks (same block size), so fully-masked
blocks are never touched; the diagonal block applies the triangular mask.

interpret=True as everywhere in this repo (CPU PJRT cannot run Mosaic).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, block_q: int, block_k: int,
                 scale: float):
    qi = pl.program_id(1)
    q = q_ref[0, :, :] * scale  # (block_q, dh)
    dh = q.shape[-1]

    def body(j, carry):
        acc, m_i, l_i = carry
        k_blk = pl.load(k_ref, (0, pl.dslice(j * block_k, block_k),
                                slice(None)))  # (block_k, dh)
        v_blk = pl.load(v_ref, (0, pl.dslice(j * block_k, block_k),
                                slice(None)))
        s = jnp.dot(q, k_blk.T, preferred_element_type=jnp.float32)
        # causal mask: global query row >= global key row
        q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                        (block_q, block_k), 0)
        k_pos = j * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                       (block_q, block_k), 1)
        s = jnp.where(q_pos >= k_pos, s, NEG_INF)
        m_new = jnp.maximum(m_i, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_i - m_new)
        l_new = alpha * l_i + jnp.sum(p, axis=1, keepdims=True)
        acc = acc * alpha + jnp.dot(p, v_blk,
                                    preferred_element_type=jnp.float32)
        return acc, m_new, l_new

    acc0 = jnp.zeros((block_q, dh), jnp.float32)
    m0 = jnp.full((block_q, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q, 1), jnp.float32)
    acc, m_i, l_i = jax.lax.fori_loop(0, qi + 1, body, (acc0, m0, l0))
    o_ref[0, :, :] = (acc / l_i).astype(o_ref.dtype)


def _pick_block(t: int, requested: int) -> int:
    b = min(requested, t)
    while t % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("block_q",))
def mha_causal(q, k, v, block_q: int = 32):
    """Causal attention over (BH, T, dh) tensors (batch*heads flattened).

    Returns (BH, T, dh); softmax in f32 regardless of input dtype.
    """
    bh, t, dh = q.shape
    bq = _pick_block(t, block_q)
    scale = 1.0 / (dh ** 0.5)
    grid = (bh, t // bq)
    kernel = functools.partial(_attn_kernel, block_q=bq, block_k=bq,
                               scale=scale)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, t, dh), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, t, dh), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, t, dh), q.dtype),
        interpret=True,
    )(q, k, v)


def mha_causal_4d(q, k, v, block_q: int = 32):
    """(B, H, T, dh) convenience wrapper."""
    B, H, T, dh = q.shape
    out = mha_causal(q.reshape(B * H, T, dh), k.reshape(B * H, T, dh),
                     v.reshape(B * H, T, dh), block_q=block_q)
    return out.reshape(B, H, T, dh)
