"""AOT pipeline: lower every L2 entry point to HLO *text* + write the manifest.

Run once via ``make artifacts``.  The interchange format is HLO text, NOT a
serialized HloModuleProto: jax >= 0.5 emits protos with 64-bit instruction
ids which the rust side's xla_extension 0.5.1 rejects (`proto.id() <=
INT_MAX`); the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

``artifacts/manifest.json`` records, for every artifact, the exact ordered
input/output signature — that file is the ABI the rust runtime
(`rust/src/runtime/`) loads at startup.
"""

import argparse
import hashlib
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .configs import CONFIGS, param_spec, target_spec, site_spec, \
    lowrank_rank
from . import model as M


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype="f32"):
    return jax.ShapeDtypeStruct(
        shape, {"f32": jnp.float32, "i32": jnp.int32}[dtype])


def _sig(entries):
    """[(name, shape, dtype)] -> manifest signature records."""
    return [{"name": n, "shape": list(s), "dtype": d} for n, s, d in entries]


def lower_artifact(fn, in_entries, out_entries, path):
    """Lower `fn` at the given input signature and write HLO text."""
    t0 = time.time()
    args = [_spec(tuple(s), d) for _, s, d in in_entries]
    lowered = jax.jit(fn).lower(*args)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    dt = time.time() - t0
    print(f"  wrote {os.path.basename(path):40s} "
          f"{len(text) / 1e6:6.2f} MB  in {dt:5.1f}s", flush=True)
    return {
        "file": os.path.basename(path),
        "inputs": _sig(in_entries),
        "outputs": _sig(out_entries),
        "sha256": hashlib.sha256(text.encode()).hexdigest(),
    }


def build_config(cfg, out_dir, fast=False):
    pspec = param_spec(cfg)
    tspec = target_spec(cfg)
    sspec = site_spec(cfg)
    B, T, V = cfg.batch, cfg.seq_len, cfg.vocab

    params_in = [(n, s, "f32") for n, s in pspec]
    tok = ("tokens_io", (B, T + 1), "i32")
    tok1 = ("tokens_io", (1, T + 1), "i32")

    arts = {}

    # --- dense forward (loss + logits) ---
    arts["fwd"] = lower_artifact(
        M.make_fwd_loss(cfg), params_in + [tok],
        [("loss", (), "f32"), ("logits", (B, T, V), "f32")],
        os.path.join(out_dir, f"{cfg.name}_fwd.hlo.txt"))
    if cfg.name == "tiny":
        arts["fwd_b1"] = lower_artifact(
            M.make_fwd_loss(cfg), params_in + [tok1],
            [("loss", (), "f32"), ("logits", (1, T, V), "f32")],
            os.path.join(out_dir, f"{cfg.name}_fwd_b1.hlo.txt"))

    # --- calibration gradients for target matrices ---
    arts["grads"] = lower_artifact(
        M.make_grads(cfg), params_in + [tok],
        [("loss", (), "f32")] + [(n, s, "f32") for n, s, _ in tspec],
        os.path.join(out_dir, f"{cfg.name}_grads.hlo.txt"))

    # --- whitening-site activation moments ---
    mom_out = [("loss", (), "f32")]
    for s, n in sspec:
        mom_out += [(s + ".xx", (n, n), "f32"), (s + ".sum", (n,), "f32"),
                    (s + ".abssum", (n,), "f32")]
    arts["moments"] = lower_artifact(
        M.make_moments(cfg), params_in + [tok], mom_out,
        os.path.join(out_dir, f"{cfg.name}_moments.hlo.txt"))

    # --- Adam train step ---
    m_in = [("m." + n, s, "f32") for n, s in pspec]
    v_in = [("v." + n, s, "f32") for n, s in pspec]
    extra = [("step", (), "i32"), ("lr", (), "f32"), tok]
    train_out = ([(n, s, "f32") for n, s in pspec]
                 + m_in + v_in + [("loss", (), "f32")])
    arts["train"] = lower_artifact(
        M.make_train_step(cfg), params_in + m_in + v_in + extra, train_out,
        os.path.join(out_dir, f"{cfg.name}_train.hlo.txt"))

    # --- pallas low-rank forwards at the uniform-rank grid ---
    lowrank = {}
    for ratio in cfg.lowrank_ratios:
        base, facts = M.lowrank_io_spec(cfg, ratio)
        in_ent = ([(n, s, "f32") for n, s in base]
                  + [(n, s, "f32") for n, s in facts] + [tok])
        tag = f"{int(ratio * 100)}"
        rec = lower_artifact(
            M.make_fwd_lowrank(cfg, ratio), in_ent,
            [("loss", (), "f32"), ("logits", (B, T, V), "f32")],
            os.path.join(out_dir, f"{cfg.name}_lowrank_r{tag}.hlo.txt"))
        rec["ranks"] = {n: lowrank_rank(ratio, mm, nn)
                        for n, (mm, nn), _ in tspec}
        lowrank[tag] = rec
        if cfg.name == "tiny" and ratio in (0.6, 0.4):
            in_ent1 = in_ent[:-1] + [tok1]
            rec1 = lower_artifact(
                M.make_fwd_lowrank(cfg, ratio), in_ent1,
                [("loss", (), "f32"), ("logits", (1, T, V), "f32")],
                os.path.join(out_dir, f"{cfg.name}_lowrank_r{tag}_b1.hlo.txt"))
            rec1["ranks"] = rec["ranks"]
            lowrank[tag + "_b1"] = rec1
    if lowrank:
        arts["lowrank"] = lowrank

    return {
        "arch": cfg.arch,
        "vocab": V, "d_model": cfg.d_model, "n_layers": cfg.n_layers,
        "n_heads": cfg.n_heads, "d_ff": cfg.d_ff,
        "seq_len": T, "batch": B,
        "params": [{"name": n, "shape": list(s)} for n, s in pspec],
        "targets": [{"name": n, "shape": list(s), "site": site}
                    for n, s, site in tspec],
        "sites": [{"name": s, "dim": n} for s, n in sspec],
        "artifacts": arts,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--configs", default="tiny,small,opt_tiny",
                    help="comma-separated subset of configs to build")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {"version": 1, "configs": {}}
    for name in args.configs.split(","):
        cfg = CONFIGS[name.strip()]
        print(f"config {cfg.name} ({cfg.arch}) "
              f"d={cfg.d_model} L={cfg.n_layers} ff={cfg.d_ff}", flush=True)
        manifest["configs"][cfg.name] = build_config(cfg, args.out_dir)

    path = os.path.join(args.out_dir, "manifest.json")
    with open(path, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {path}")


if __name__ == "__main__":
    sys.exit(main())
