"""L2: the transformer model in JAX — forward, loss, grads, moments, train step.

Everything here is build-time only.  `aot.py` lowers the functions below to
HLO text once; the rust runtime executes them via PJRT forever after.

Parameters are a flat dict name->array; the canonical ordering (the rust ABI)
comes from `configs.param_spec`.  Two architectures:

* ``llama`` — RMSNorm, RoPE, causal MHA, SwiGLU MLP, tied embedding head.
* ``opt``   — learned positions, (scale-only) LayerNorm, GELU MLP, tied head.

The *low-rank* forward replaces every compression-target matmul with the L1
Pallas kernel `kernels.lowrank_linear_3d`, so the lowered HLO exercises the
fused VMEM-resident factored contraction on the serving path.
"""

import jax
import jax.numpy as jnp

from .configs import ModelConfig, param_spec, target_spec, site_spec, \
    lowrank_rank
from .kernels.lowrank import lowrank_linear_3d


# ---------------------------------------------------------------------------
# initialization (used by python tests; the rust trainer has its own
# identically-scaled initializer, see rust/src/model/init.rs)
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key) -> dict:
    params = {}
    for name, shape in param_spec(cfg):
        key, sub = jax.random.split(key)
        if name.endswith(("ln1", "ln2", "final_ln")):
            params[name] = jnp.ones(shape, jnp.float32)
        elif name == "pos_embed":
            params[name] = 0.02 * jax.random.normal(sub, shape, jnp.float32)
        else:
            scale = 0.02
            if name.endswith(("wo", "wdown", "wout")):
                # residual-branch output scaling (GPT-2 style)
                scale = 0.02 / (2 * cfg.n_layers) ** 0.5
            params[name] = scale * jax.random.normal(sub, shape, jnp.float32)
    return params


# ---------------------------------------------------------------------------
# building blocks
# ---------------------------------------------------------------------------

def rmsnorm(x, scale, eps):
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * scale


def layernorm(x, scale, eps):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * scale


def rope(x, theta):
    """Rotary embedding over (B, H, T, dh)."""
    b, h, t, dh = x.shape
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    pos = jnp.arange(t, dtype=jnp.float32)
    ang = pos[:, None] * freqs[None, :]            # (T, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def causal_attention(q, k, v):
    """Reference causal attention over (B, H, T, dh) in f32."""
    dh = q.shape[-1]
    s = jnp.einsum("bhtd,bhsd->bhts", q, k) / jnp.sqrt(jnp.float32(dh))
    t = q.shape[2]
    mask = jnp.tril(jnp.ones((t, t), bool))
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhts,bhsd->bhtd", p, v)


def _split_heads(x, n_heads):
    b, t, d = x.shape
    return x.reshape(b, t, n_heads, d // n_heads).transpose(0, 2, 1, 3)


def _merge_heads(x):
    b, h, t, dh = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, t, h * dh)


def dense(x, w):
    """y = x @ w^T for w stored (out, in) — the paper's W in R^{m x n}."""
    return jnp.einsum("btn,mn->btm", x, w)


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def forward(cfg: ModelConfig, params: dict, tokens, collect_sites=False,
            lowrank=None):
    """Token logits (+ optionally the whitening-site activations).

    Args:
      tokens: (B, T) int32 input ids.
      collect_sites: if True, also return {site_name: (B,T,n) activations}.
      lowrank: optional {target_name: (wu, wv)}; those matmuls run through
        the Pallas low-rank kernel instead of the dense weight.
    """
    sites = {}
    norm = rmsnorm if cfg.arch == "llama" else layernorm

    def linear(name, x):
        if lowrank is not None and name in lowrank:
            wu, wv = lowrank[name]
            return lowrank_linear_3d(x, wu, wv)
        return dense(x, params[name])

    x = params["embed"][tokens]                      # (B, T, d)
    if cfg.arch == "opt":
        x = x + params["pos_embed"][None, : tokens.shape[1]]

    for i in range(cfg.n_layers):
        p = f"layers.{i}."
        h = norm(x, params[p + "ln1"], cfg.norm_eps)
        if collect_sites:
            sites[p + "attn_in"] = h
        q = _split_heads(linear(p + "wq", h), cfg.n_heads)
        k = _split_heads(linear(p + "wk", h), cfg.n_heads)
        v = _split_heads(linear(p + "wv", h), cfg.n_heads)
        if cfg.arch == "llama":
            q, k = rope(q, cfg.rope_theta), rope(k, cfg.rope_theta)
        attn = _merge_heads(causal_attention(q, k, v))
        if collect_sites:
            sites[p + "attn_out_in"] = attn
        x = x + linear(p + "wo", attn)

        h = norm(x, params[p + "ln2"], cfg.norm_eps)
        if collect_sites:
            sites[p + "mlp_in"] = h
        if cfg.arch == "llama":
            g = linear(p + "wgate", h)
            u = linear(p + "wup", h)
            act = jax.nn.silu(g) * u
            if collect_sites:
                sites[p + "mlp_down_in"] = act
            x = x + linear(p + "wdown", act)
        else:
            act = jax.nn.gelu(linear(p + "win", h))
            if collect_sites:
                sites[p + "mlp_down_in"] = act
            x = x + linear(p + "wout", act)

    x = norm(x, params["final_ln"], cfg.norm_eps)
    logits = jnp.einsum("btd,vd->btv", x, params["embed"])  # tied head
    if collect_sites:
        return logits, sites
    return logits


def loss_fn(cfg: ModelConfig, params: dict, tokens_io, lowrank=None):
    """Mean next-token cross-entropy. tokens_io: (B, T+1) int32."""
    inp, tgt = tokens_io[:, :-1], tokens_io[:, 1:]
    logits = forward(cfg, params, inp, lowrank=lowrank)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    return jnp.mean(nll), logits


# ---------------------------------------------------------------------------
# AOT entry points (lowered by aot.py; signatures are the rust ABI)
# ---------------------------------------------------------------------------

def make_fwd_loss(cfg: ModelConfig):
    """(params..., tokens_io) -> (loss, logits)."""
    names = [n for n, _ in param_spec(cfg)]

    def f(*args):
        params = dict(zip(names, args[:-1]))
        loss, logits = loss_fn(cfg, params, args[-1])
        return (loss, logits)

    return f


def make_grads(cfg: ModelConfig):
    """(params..., tokens_io) -> (loss, grad per target matrix)."""
    names = [n for n, _ in param_spec(cfg)]
    tnames = [t[0] for t in target_spec(cfg)]

    def f(*args):
        params = dict(zip(names, args[:-1]))
        tokens = args[-1]
        frozen = {k: v for k, v in params.items() if k not in tnames}

        def scalar_loss(tparams):
            return loss_fn(cfg, {**frozen, **tparams}, tokens)[0]

        tparams = {k: params[k] for k in tnames}
        loss, grads = jax.value_and_grad(scalar_loss)(tparams)
        return (loss,) + tuple(grads[k] for k in tnames)

    return f


def make_moments(cfg: ModelConfig):
    """(params..., tokens_io) -> (loss, then per site: XX^T, sum_x, sum_|x|).

    X is the (n, B*T) matrix of site inputs; the rust side accumulates over
    calibration batches, adds the ridge, and Cholesky-factors.  sum_x and
    sum_|x| feed the FLAP-like and ASVD baselines.  The loss output is not
    just convenience: it anchors the full forward graph so XLA cannot prune
    parameters that only feed the logits (final_ln, the last down-proj) from
    the lowered signature — the rust ABI assumes every param is an input.
    """
    names = [n for n, _ in param_spec(cfg)]
    snames = [s for s, _ in site_spec(cfg)]

    def f(*args):
        params = dict(zip(names, args[:-1]))
        tokens_io = args[-1]
        inp, tgt = tokens_io[:, :-1], tokens_io[:, 1:]
        logits, sites = forward(cfg, params, inp, collect_sites=True)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
        outs = [jnp.mean(nll)]
        for s in snames:
            x = sites[s].astype(jnp.float32)
            n = x.shape[-1]
            flat = x.reshape(-1, n)
            outs.append(flat.T @ flat)            # (n, n)
            outs.append(jnp.sum(flat, axis=0))    # (n,)
            outs.append(jnp.sum(jnp.abs(flat), axis=0))
        return tuple(outs)

    return f


def make_train_step(cfg: ModelConfig, beta1=0.9, beta2=0.95, eps=1e-8,
                    weight_decay=0.0):
    """(params..., m..., v..., step, lr, tokens_io)
       -> (params'..., m'..., v'..., loss).   Plain Adam."""
    names = [n for n, _ in param_spec(cfg)]
    P = len(names)

    def f(*args):
        params = dict(zip(names, args[:P]))
        m = dict(zip(names, args[P:2 * P]))
        v = dict(zip(names, args[2 * P:3 * P]))
        step, lr, tokens = args[3 * P], args[3 * P + 1], args[3 * P + 2]

        def scalar_loss(p):
            return loss_fn(cfg, p, tokens)[0]

        loss, grads = jax.value_and_grad(scalar_loss)(params)
        t = step.astype(jnp.float32) + 1.0
        bc1 = 1.0 - beta1 ** t
        bc2 = 1.0 - beta2 ** t
        new_p, new_m, new_v = [], [], []
        for n in names:
            g = grads[n]
            if weight_decay > 0.0 and g.ndim >= 2:
                g = g + weight_decay * params[n]
            mn = beta1 * m[n] + (1 - beta1) * g
            vn = beta2 * v[n] + (1 - beta2) * jnp.square(g)
            upd = (mn / bc1) / (jnp.sqrt(vn / bc2) + eps)
            new_p.append(params[n] - lr * upd)
            new_m.append(mn)
            new_v.append(vn)
        return tuple(new_p) + tuple(new_m) + tuple(new_v) + (loss,)

    return f


def make_fwd_lowrank(cfg: ModelConfig, ratio: float):
    """Low-rank forward at the closed-form uniform rank for `ratio`.

    Inputs: non-target params in canonical order, then (wu, wv) per target in
    target order, then tokens_io.  Output: (loss, logits).
    Target matmuls run through the L1 Pallas kernel.
    """
    pspec = param_spec(cfg)
    tspec = target_spec(cfg)
    tnames = {t[0] for t in tspec}
    base_names = [n for n, _ in pspec if n not in tnames]

    def f(*args):
        params = dict(zip(base_names, args[:len(base_names)]))
        lowrank = {}
        idx = len(base_names)
        for name, _, _ in tspec:
            lowrank[name] = (args[idx], args[idx + 1])
            idx += 2
        tokens = args[idx]
        loss, logits = loss_fn(cfg, params, tokens, lowrank=lowrank)
        return (loss, logits)

    return f


def lowrank_io_spec(cfg: ModelConfig, ratio: float):
    """(base_param_shapes, factored_shapes) for `make_fwd_lowrank` inputs."""
    pspec = param_spec(cfg)
    tspec = target_spec(cfg)
    tnames = {t[0] for t in tspec}
    base = [(n, s) for n, s in pspec if n not in tnames]
    facts = []
    for name, (mm, nn), _ in tspec:
        k = lowrank_rank(ratio, mm, nn)
        facts.append((name + ".wu", (mm, k)))
        facts.append((name + ".wv", (k, nn)))
    return base, facts
