//! Minimal offline shim of the `anyhow` API surface used by `zs-svd`.
//!
//! The real crate is unavailable in offline builds; this shim provides the
//! subset the codebase relies on — `Error`, `Result`, the `anyhow!` /
//! `bail!` / `ensure!` macros, and the `Context` extension trait — with the
//! same call-site syntax.  Errors are flattened to strings: context frames
//! are prepended `"context: cause"` exactly like `anyhow`'s Display chain.

use std::fmt;

/// String-backed error value.  Cheap, `Send + Sync`, and good enough for a
/// binary that only ever formats its errors.
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from anything printable (mirrors `anyhow::Error::msg`).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string() }
    }

    /// Wrap with an outer context frame.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: format!("{context}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

/// `From` impls for the concrete error types the codebase propagates with
/// `?` into `anyhow::Result`.
macro_rules! impl_from {
    ($($ty:ty),* $(,)?) => {
        $(impl From<$ty> for Error {
            fn from(e: $ty) -> Error {
                Error::msg(e)
            }
        })*
    };
}

impl_from!(
    std::io::Error,
    std::str::Utf8Error,
    std::string::FromUtf8Error,
    std::num::ParseIntError,
    std::num::ParseFloatError,
    std::fmt::Error,
    String,
);

impl From<&str> for Error {
    fn from(e: &str) -> Error {
        Error::msg(e)
    }
}

/// Drop-in for `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)` to results.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{context}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return Err($crate::anyhow!($($arg)+))
    };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!(
                "condition failed: ", stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails(flag: bool) -> Result<u32> {
        ensure!(flag, "flag was {flag}");
        Ok(7)
    }

    #[test]
    fn macros_and_context() {
        assert_eq!(fails(true).unwrap(), 7);
        let e = fails(false).unwrap_err();
        assert_eq!(e.to_string(), "flag was false");
        let e = anyhow!("x = {}", 3).context("outer");
        assert_eq!(e.to_string(), "outer: x = 3");
        let r: Result<()> = Err(anyhow!("inner"));
        let r = r.with_context(|| "while testing");
        assert_eq!(r.unwrap_err().to_string(), "while testing: inner");
    }

    #[test]
    fn question_mark_conversions() {
        fn io() -> Result<()> {
            std::fs::read("/definitely/not/a/path/zs-svd-test")?;
            Ok(())
        }
        assert!(io().is_err());
    }
}
