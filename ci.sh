#!/usr/bin/env bash
# CI entry point: lint → build → tier-1 tests → bench smoke.
#
# fmt defaults to advisory (warn, don't fail) because the build box may
# lack the rustfmt component; set ZS_CI_STRICT=1 to make it fatal.  clippy
# is FATAL whenever the component is installed (`-D warnings`); only its
# absence is advisory.  The correctness gate is always fatal:
# `cargo build --release && cargo test -q` plus the microbench smoke run.
set -euo pipefail
cd "$(dirname "$0")"

lint_fail() {
    if [ "${ZS_CI_STRICT:-0}" = "1" ]; then
        echo "FATAL: $1 (ZS_CI_STRICT=1)"
        exit 1
    fi
    echo "WARN: $1 (non-fatal; set ZS_CI_STRICT=1 to enforce)"
}

echo "== cargo fmt --check =="
if cargo fmt --version >/dev/null 2>&1; then
    cargo fmt --check || lint_fail "rustfmt differences"
else
    lint_fail "rustfmt unavailable"
fi

echo "== cargo clippy --all-targets -D warnings (fatal) =="
if cargo clippy --version >/dev/null 2>&1; then
    # fatal, not advisory: the tree is clippy-clean, keep it that way
    cargo clippy --all-targets -- -D warnings
else
    lint_fail "clippy unavailable"
fi

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

echo "== cargo test -q (PALLAS_NO_SIMD=1: portable kernel backend) =="
# both kernel backends must stay green: the whole suite re-runs with the
# SIMD layer forced onto the portable lane-strided fallback.  The backends
# are bit-identical by contract (rust/tests/kernel_equiv.rs is the direct
# gate), so every parity test proves its invariant on both.
PALLAS_NO_SIMD=1 cargo test -q

echo "== cargo doc --no-deps (RUSTDOCFLAGS=-D warnings) =="
# the doc gate is fatal: rustdoc ships with the toolchain (unlike the
# rustfmt/clippy components), and the crate enforces #![warn(missing_docs)]
# — so a broken intra-doc link or an undocumented public item fails CI here
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

echo "== bench smoke: microbench_linalg (ZS_BENCH_FAST=1) =="
ZS_BENCH_FAST=1 cargo bench --bench microbench_linalg

echo "== decode smoke: decode_throughput (ZS_BENCH_FAST=1) =="
# tiny config, a few generated tokens, dense + low-rank engines through the
# KV-cached continuous-batching path (checkpoint-cached training reused)
ZS_BENCH_FAST=1 cargo bench --bench decode_throughput

echo "== server smoke: server_throughput (ZS_BENCH_FAST=1) =="
# dense + low-rank engines behind the TCP front-end, loopback client fleet
ZS_BENCH_FAST=1 cargo bench --bench server_throughput

serve_smoke() {
    # start the network server on an OS-assigned port (extra server flags in
    # "$@"), run a short scripted client session (streamed completions +
    # metrics), then drain it via the protocol shutdown and require a clean
    # exit
    PORT_FILE="$(mktemp)"
    rm -f "$PORT_FILE"
    ./target/release/zs-svd serve --listen 127.0.0.1:0 \
        --port-file "$PORT_FILE" --max-new-tokens 4 --fast "$@" &
    SRV_PID=$!
    # never leave the background server orphaned: if the client (or anything
    # below) fails under `set -e`, kill it on the way out
    trap 'kill "$SRV_PID" 2>/dev/null || true' EXIT
    for _ in $(seq 1 600); do
        [ -s "$PORT_FILE" ] && break
        if ! kill -0 "$SRV_PID" 2>/dev/null; then
            echo "FATAL: server exited before binding"
            exit 1
        fi
        sleep 0.5
    done
    if [ ! -s "$PORT_FILE" ]; then
        echo "FATAL: server never wrote its port file"
        kill "$SRV_PID" 2>/dev/null || true
        exit 1
    fi
    ./target/release/zs-svd client --connect "$(cat "$PORT_FILE")" \
        --requests 2 --prompt-len 8 --max-new-tokens 4 --shutdown
    wait "$SRV_PID"
    trap - EXIT
    rm -f "$PORT_FILE"
}

echo "== server loopback smoke: serve --listen + scripted client =="
serve_smoke
echo "server smoke OK (clean streamed completion + shutdown)"

echo "== speculative serve smoke: serve --listen --speculate-k 2 =="
# same round-trip with the dense target speculating through the ZS-SVD
# drafter (--draft-ratio default 0.4): streamed tokens are bit-identical
# by construction (rust/tests/server_loopback.rs gates that); this smoke
# proves the CLI drafter wiring end-to-end
serve_smoke --speculate-k 2
echo "speculative serve smoke OK (drafter round-trip + shutdown)"

echo "== prefix-cache smoke: serve --prefix-cache + repeated prompts =="
# two scripted client sessions send the IDENTICAL prompt (the scripted
# prompt is deterministic and per-session ids restart at 0): the first
# prefills cold and populates the prefix tree, the second must hit it —
# asserted through the wire metrics counter — while streaming bit-identical
# tokens (diffed from the printed token ids)
PORT_FILE="$(mktemp)"
rm -f "$PORT_FILE"
./target/release/zs-svd serve --listen 127.0.0.1:0 \
    --port-file "$PORT_FILE" --max-new-tokens 4 --fast \
    --prefix-cache 64 --kv-block 8 &
SRV_PID=$!
trap 'kill "$SRV_PID" 2>/dev/null || true' EXIT
for _ in $(seq 1 600); do
    [ -s "$PORT_FILE" ] && break
    if ! kill -0 "$SRV_PID" 2>/dev/null; then
        echo "FATAL: prefix-cache server exited before binding"
        exit 1
    fi
    sleep 0.5
done
[ -s "$PORT_FILE" ] || { echo "FATAL: server never wrote its port file"; exit 1; }
OUT1="$(./target/release/zs-svd client --connect "$(cat "$PORT_FILE")" \
    --requests 1 --prompt-len 24 --max-new-tokens 4)"
OUT2="$(./target/release/zs-svd client --connect "$(cat "$PORT_FILE")" \
    --requests 1 --prompt-len 24 --max-new-tokens 4 --shutdown)"
wait "$SRV_PID"
trap - EXIT
rm -f "$PORT_FILE"
# the warm session's metrics must show prefix-cache hits...
echo "$OUT2" | grep -Eq '[1-9][0-9]* prompt tokens served from prefix cache' \
    || { echo "FATAL: second session reported no prefix-cache hits";
         echo "$OUT2"; exit 1; }
# ...and both sessions must have streamed the same token ids
TOK1="$(echo "$OUT1" | grep -F 'tokens: [')"
TOK2="$(echo "$OUT2" | grep -F 'tokens: [')"
[ -n "$TOK1" ] && [ "$TOK1" = "$TOK2" ] \
    || { echo "FATAL: prefix-cache hit changed streamed tokens";
         echo "cold: $TOK1"; echo "warm: $TOK2"; exit 1; }
echo "prefix-cache smoke OK (warm hit via metrics, tokens bit-identical)"

echo "== trace smoke: serve --trace-out + chrome-trace validation =="
# the same serve round-trip with the observability layer on: the server
# writes a chrome://tracing JSON on shutdown, and the binary's own `trace`
# subcommand re-parses it with the in-repo util::json — queue/prefill/decode
# request spans and engine spans must come out structurally well-formed
TRACE_FILE="$(mktemp)"
serve_smoke --trace-out "$TRACE_FILE"
./target/release/zs-svd trace "$TRACE_FILE"
rm -f "$TRACE_FILE"
echo "trace smoke OK (chrome trace written + validated)"

echo "== compress report smoke: compress --report + validation =="
# per-matrix selection report (rank, predicted ΔL, zero-sum trajectory)
# through the same validator; reuses the --fast checkpoint trained above
REPORT_FILE="$(mktemp)"
./target/release/zs-svd compress --fast --ratio 0.5 --report "$REPORT_FILE"
./target/release/zs-svd trace "$REPORT_FILE"
rm -f "$REPORT_FILE"
echo "compress report smoke OK (report written + validated)"

echo "== artifact smoke: pack → corrupt one chunk → install must fail =="
# pack a ratio-0.6 plan into a fresh store, truncate one content-addressed
# chunk by a single byte, and require `install` to reject it WITHOUT
# committing a manifest at the destination; then heal the store (re-pack
# overwrites the invalid chunk) and require the clean install to commit
ART_SRC="$(mktemp -d)/store"
ART_DST="$(mktemp -d)/store"
./target/release/zs-svd pack --fast --ratio 0.6 --out "$ART_SRC"
MANIFEST="$ART_SRC/tiny-zs60.zsar"
[ -f "$MANIFEST" ] || { echo "FATAL: pack wrote no manifest"; exit 1; }
CHUNK="$(ls -S "$ART_SRC/chunks" | head -n 1)"
truncate -s -1 "$ART_SRC/chunks/$CHUNK"
if ./target/release/zs-svd install --from "$MANIFEST" --to "$ART_DST"; then
    echo "FATAL: install succeeded on a corrupted chunk"; exit 1
fi
[ ! -e "$ART_DST/tiny-zs60.zsar" ] \
    || { echo "FATAL: failed install left a manifest visible"; exit 1; }
./target/release/zs-svd pack --fast --ratio 0.6 --out "$ART_SRC"
./target/release/zs-svd install --from "$MANIFEST" --to "$ART_DST"
[ -f "$ART_DST/tiny-zs60.zsar" ] \
    || { echo "FATAL: clean install wrote no manifest"; exit 1; }
echo "artifact smoke OK (corruption rejected, clean install committed)"

echo "== artifact reload smoke: serve --artifact + live client --reload =="
# serve straight from the installed artifact, run one plain session, then a
# second session that hot-swaps the SAME artifact before generating: the
# wire metrics must report exactly one swap and the streamed token ids must
# be bit-identical across the swap (same plan in → same tokens out)
PORT_FILE="$(mktemp)"
rm -f "$PORT_FILE"
./target/release/zs-svd serve --listen 127.0.0.1:0 \
    --port-file "$PORT_FILE" --max-new-tokens 4 --fast \
    --artifact "$ART_DST/tiny-zs60.zsar" &
SRV_PID=$!
trap 'kill "$SRV_PID" 2>/dev/null || true' EXIT
for _ in $(seq 1 600); do
    [ -s "$PORT_FILE" ] && break
    if ! kill -0 "$SRV_PID" 2>/dev/null; then
        echo "FATAL: artifact server exited before binding"
        exit 1
    fi
    sleep 0.5
done
[ -s "$PORT_FILE" ] || { echo "FATAL: server never wrote its port file"; exit 1; }
OUT1="$(./target/release/zs-svd client --connect "$(cat "$PORT_FILE")" \
    --requests 1 --prompt-len 8 --max-new-tokens 4)"
OUT2="$(./target/release/zs-svd client --connect "$(cat "$PORT_FILE")" \
    --requests 1 --prompt-len 8 --max-new-tokens 4 \
    --reload "$ART_DST/tiny-zs60.zsar" --shutdown)"
wait "$SRV_PID"
trap - EXIT
rm -f "$PORT_FILE"
echo "$OUT2" | grep -Fq 'artifact swaps: 1' \
    || { echo "FATAL: reload session reported no swap"; echo "$OUT2"; exit 1; }
TOK1="$(echo "$OUT1" | grep -F 'tokens: [')"
TOK2="$(echo "$OUT2" | grep -F 'tokens: [')"
[ -n "$TOK1" ] && [ "$TOK1" = "$TOK2" ] \
    || { echo "FATAL: hot swap changed streamed tokens";
         echo "pre:  $TOK1"; echo "post: $TOK2"; exit 1; }
rm -rf "$(dirname "$ART_SRC")" "$(dirname "$ART_DST")"
echo "artifact reload smoke OK (swap counter + tokens bit-identical)"

echo "== fleet smoke: router + 2 workers, kill -9 one, auto-restart =="
# one packed artifact behind a supervised 2-worker fleet: run a scripted
# session through the routed address, kill -9 one worker, and require the
# router to (a) keep serving, (b) report exactly one restart through the
# fleet metrics the client prints, (c) stream bit-identical tokens for the
# re-issued request after the restart
FLEET_STORE="$(mktemp -d)/store"
./target/release/zs-svd pack --fast --ratio 0.6 --out "$FLEET_STORE"
FLEET_A="$FLEET_STORE/tiny-zs60.zsar"
[ -f "$FLEET_A" ] || { echo "FATAL: pack wrote no fleet manifest"; exit 1; }
PORT_FILE="$(mktemp)"
rm -f "$PORT_FILE"
./target/release/zs-svd router --workers 2 --listen 127.0.0.1:0 \
    --port-file "$PORT_FILE" --artifact "$FLEET_A" --max-new-tokens 4 &
RTR_PID=$!
trap 'kill "$RTR_PID" 2>/dev/null || true' EXIT
for _ in $(seq 1 600); do
    [ -s "$PORT_FILE" ] && break
    if ! kill -0 "$RTR_PID" 2>/dev/null; then
        echo "FATAL: router exited before binding"
        exit 1
    fi
    sleep 0.5
done
[ -s "$PORT_FILE" ] || { echo "FATAL: router never wrote its port file"; exit 1; }
FLEET_ADDR="$(cat "$PORT_FILE")"
# first session: requests queue until the workers pass their handshake,
# so this also proves boot; then wait until BOTH workers report healthy
OUT1="$(./target/release/zs-svd client --connect "$FLEET_ADDR" \
    --requests 2 --prompt-len 8 --max-new-tokens 4 --retries 3)"
echo "$OUT1" | grep -Fq 'fleet worker restarts: 0' \
    || { echo "FATAL: fresh fleet already reported restarts"; echo "$OUT1"; exit 1; }
W0_PID=""
for _ in $(seq 1 240); do
    POLL="$(./target/release/zs-svd client --connect "$FLEET_ADDR" \
        --requests 1 --prompt-len 8 --max-new-tokens 4 --retries 3 || true)"
    if echo "$POLL" | grep -Eq '^fleet worker 0: pid [1-9][0-9]* healthy true' \
        && echo "$POLL" | grep -Eq '^fleet worker 1: pid [1-9][0-9]* healthy true'; then
        W0_PID="$(echo "$POLL" | grep -E '^fleet worker 0:' | awk '{print $5}')"
        break
    fi
    sleep 0.5
done
[ -n "$W0_PID" ] || { echo "FATAL: fleet never reached 2 healthy workers"; exit 1; }
kill -9 "$W0_PID"
# the supervisor must notice, restart worker 0 from the same artifact, and
# keep the routed address serving throughout (--retries rides out any
# request caught on the dying worker)
RESTARTED=""
for _ in $(seq 1 240); do
    OUT2="$(./target/release/zs-svd client --connect "$FLEET_ADDR" \
        --requests 1 --prompt-len 8 --max-new-tokens 4 --retries 5 || true)"
    if echo "$OUT2" | grep -Fq 'fleet worker restarts: 1' \
        && echo "$OUT2" | grep -Eq '^fleet worker 0: pid [1-9][0-9]* healthy true'; then
        RESTARTED=1
        break
    fi
    sleep 0.5
done
[ -n "$RESTARTED" ] \
    || { echo "FATAL: killed worker never restarted"; echo "$OUT2"; exit 1; }
# the post-restart session re-issued request 0 (same scripted prompt):
# tokens must be bit-identical to the pre-kill session's request 0
TOK1="$(echo "$OUT1" | grep -F 'request 0 tokens: [')"
TOK2="$(echo "$OUT2" | grep -F 'request 0 tokens: [')"
[ -n "$TOK1" ] && [ "$TOK1" = "$TOK2" ] \
    || { echo "FATAL: restart changed streamed tokens";
         echo "pre-kill:     $TOK1"; echo "post-restart: $TOK2"; exit 1; }
echo "fleet kill smoke OK (restart observed, post-restart tokens bit-identical)"

echo "== fleet reload smoke: fleet-wide reload with one corrupted store =="
# pack a second plan and install a copy whose store is then corrupted:
# a per-worker reload fan-out (good path for worker 0, corrupt for worker
# 1) must swap ONLY worker 0, name both outcomes in the structured error,
# and leave the fleet serving; a follow-up valid fleet-wide reload must
# converge it and drain cleanly
./target/release/zs-svd pack --fast --ratio 0.4 --out "$FLEET_STORE"
FLEET_B="$FLEET_STORE/tiny-zs40.zsar"
[ -f "$FLEET_B" ] || { echo "FATAL: pack wrote no plan-B manifest"; exit 1; }
FLEET_BAD="$(mktemp -d)/store"
./target/release/zs-svd install --from "$FLEET_B" --to "$FLEET_BAD"
BAD_CHUNK="$(ls -S "$FLEET_BAD/chunks" | head -n 1)"
truncate -s -1 "$FLEET_BAD/chunks/$BAD_CHUNK"
RELOAD_OUT="$(./target/release/zs-svd client --connect "$FLEET_ADDR" \
    --reload "$FLEET_B,$FLEET_BAD/tiny-zs40.zsar" \
    --requests 1 --prompt-len 8 --max-new-tokens 4 2>&1 || true)"
echo "$RELOAD_OUT" | grep -Fq 'reload_failed' \
    || { echo "FATAL: partial reload did not fail structurally";
         echo "$RELOAD_OUT"; exit 1; }
echo "$RELOAD_OUT" | grep -Fq 'swapped [worker 0]' \
    || { echo "FATAL: partial reload did not name the swapped worker";
         echo "$RELOAD_OUT"; exit 1; }
# the split fleet must still serve plain sessions...
./target/release/zs-svd client --connect "$FLEET_ADDR" \
    --requests 1 --prompt-len 8 --max-new-tokens 4 --retries 3 >/dev/null
# ...and a valid fleet-wide path converges it; drain the fleet via the
# protocol shutdown and require a clean router exit
./target/release/zs-svd client --connect "$FLEET_ADDR" \
    --reload "$FLEET_B" --requests 1 --prompt-len 8 --max-new-tokens 4 \
    --shutdown
wait "$RTR_PID"
trap - EXIT
rm -f "$PORT_FILE"
rm -rf "$(dirname "$FLEET_STORE")" "$(dirname "$FLEET_BAD")"
echo "fleet reload smoke OK (partial failure reported, converged, clean drain)"

echo "CI OK"
